//! The loss-oracle durability harness (the `ReplPolicy` layer).
//!
//! ReCXL's resilience claim is that every *committed* update survives
//! any single node failure.  Before dump replication there was a
//! documented hole in that claim (DESIGN.md "MN failures"): an update
//! whose log entries had been dumped to an MN that later fail-stops —
//! with no surviving cache copy and the Logging Units already cleared
//! by the dump — was honestly lost, and the consistency oracle reported
//! it.  PR 9 lifts the fix into a policy layer, and these tests pin the
//! durability side of its frontier:
//!
//! * `repl=mirror` (default, the PR-5 behavior): the
//!   `mn-crash-after-dump` scenario and a 200-case randomized sweep of
//!   single-MN-failure plans complete with the oracle reporting **zero
//!   lost words** — the rebuild fetches the surviving replica dump
//!   chunks (`FetchDumpChunk`).
//! * `repl=single` (the paper-faithful baseline): the loss window still
//!   reproduces, so the regression pin keeps pinning the honest
//!   behavior the policy layer exists to fix.
//! * `repl=nway:3` and `repl=ec:2/1` both advertise `tolerance() == 2`:
//!   any two MN failures are loss-free, three near-simultaneous ones
//!   reopen the window — the policies are distinct *bandwidth* points
//!   (see `policy_bandwidth_forms_the_frontier`), not distinct
//!   durability claims.
//!
//! The loss recipe, everywhere in this file: a dump period short enough
//! that several dump cycles (which clear the Logging Units) land before
//! the crash, and caches small enough that early-written lines are
//! evicted from every cache — leaving the dumped chunks on the doomed
//! MN as the only copies.

use recxl::config::CacheGeom;
use recxl::prelude::*;
use recxl::proto::MsgClass;
use recxl::ptest::{check, knob};
use recxl::scenarios;
use recxl::sim::time::{us, Ps};

/// Shrink the cache hierarchy so written lines actually leave it
/// (whole-set geometries: 192/512/2048 lines at the stock assocs).
fn shrink_caches(cfg: &mut SimConfig) {
    cfg.l1 = CacheGeom { size_bytes: 12 * 1024, ..cfg.l1 };
    cfg.l2 = CacheGeom { size_bytes: 32 * 1024, ..cfg.l2 };
    cfg.l3 = CacheGeom { size_bytes: 128 * 1024, ..cfg.l3 };
}

// ------------------------------------------------------------- scenario

fn scenario_run(repl: ReplPolicy) -> (SimConfig, RunStats) {
    let sc = scenarios::by_name("mn-crash-after-dump").unwrap();
    let cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 6_000,
        repl,
        ..SimConfig::default()
    };
    let stats = scenarios::run_scenario(&sc, cfg.clone(), &by_name("ycsb").unwrap());
    // verdict() sees the pre-prepare() cfg, exactly like the CLI does
    scenarios::verdict(&sc, &cfg, &stats)
        .unwrap_or_else(|e| panic!("mn-crash-after-dump (repl={}): {e}", repl.name()));
    (cfg, stats)
}

#[test]
fn mn_crash_after_dump_is_loss_free_with_dump_repl() {
    let (_, s) = scenario_run(ReplPolicy::Mirror);
    assert!(s.recovery.happened);
    assert!(
        s.recovery.consistent,
        "oracle reported {} lost/corrupt words with repl=mirror",
        s.recovery.inconsistencies
    );
    // the new rebuild source must actually have fired: lines whose only
    // surviving data was a secondary dump copy
    assert!(
        s.recovery.rebuilt_dumps > 0,
        "no line was rebuilt from fetched dump copies — the scenario \
         no longer exercises the durability window"
    );
    // re-dump-on-death restored the 2-copy invariant for the orphans
    assert!(
        s.recovery.rereplicated_chunks > 0,
        "no chunk was re-replicated after the MN death"
    );
    // the durability traffic is measurable under its own class
    assert!(s.traffic.bytes_of(MsgClass::DumpRepl) > 0);
}

#[test]
fn mn_crash_after_dump_reproduces_the_loss_window_without_dump_repl() {
    let (_, s) = scenario_run(ReplPolicy::Single);
    assert!(s.recovery.happened);
    assert!(
        !s.recovery.consistent,
        "the documented loss window must reproduce with repl=single — \
         a clean run means the regression pin pins nothing"
    );
    assert!(s.recovery.inconsistencies > 0);
    // and none of the replication machinery may have run
    assert_eq!(s.recovery.rebuilt_dumps, 0);
    assert_eq!(s.traffic.bytes_of(MsgClass::DumpRepl), 0);
}

#[test]
fn dump_replication_cost_is_bounded_by_dump_traffic() {
    // no-fault mirror run: every primary chunk gets exactly one
    // same-sized replica copy, so the class is nonzero but never
    // exceeds the primary dump class (which additionally carries the
    // sync acks)
    let mut cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 6_000,
        dump_period_ps: us(12),
        repl: ReplPolicy::Mirror,
        ..SimConfig::default()
    };
    shrink_caches(&mut cfg);
    let s = run_app(cfg, &by_name("ycsb").unwrap());
    assert!(s.repl.dumps > 0, "the run must actually dump");
    let dump = s.traffic.bytes_of(MsgClass::LogDump);
    let repl = s.traffic.bytes_of(MsgClass::DumpRepl);
    assert!(repl > 0, "replica copies must ship");
    assert!(
        repl <= dump,
        "mirroring can at most double the dump stream ({repl} vs {dump})"
    );
}

#[test]
fn policy_bandwidth_forms_the_frontier() {
    // The bandwidth axis of the durability-vs-bandwidth frontier, on
    // one identical no-fault run per policy: single ships nothing;
    // mirror ships one full copy; ec:2/1 ships two half-size data
    // stripes plus one ~half-size parity chunk (~1.5x a copy); nway:3
    // ships two full copies.  The orderings below are what make nway:3
    // and ec:2/1 *distinct* frontier points at the same tolerance.
    let mut bytes = std::collections::BTreeMap::new();
    for repl in ReplPolicy::ALL {
        let mut cfg = SimConfig {
            protocol: Protocol::ReCxlProactive,
            ops_per_thread: 6_000,
            dump_period_ps: us(12),
            repl,
            ..SimConfig::default()
        };
        shrink_caches(&mut cfg);
        let s = run_app(cfg, &by_name("ycsb").unwrap());
        assert!(s.repl.dumps > 0, "{}: the run must dump", repl.name());
        bytes.insert(repl.name(), s.traffic.bytes_of(MsgClass::DumpRepl));
    }
    assert_eq!(bytes["single"], 0, "single must ship no replica bytes");
    assert!(bytes["mirror"] > 0);
    assert!(
        bytes["locality"] > 0 && bytes["locality"] < bytes["nway:3"],
        "locality re-ranks targets but still ships one copy per chunk \
         ({} vs nway {})",
        bytes["locality"],
        bytes["nway:3"]
    );
    assert!(
        bytes["nway:3"] > bytes["mirror"],
        "two copies must cost more than one ({} vs {})",
        bytes["nway:3"],
        bytes["mirror"]
    );
    assert!(
        bytes["ec:2/1"] > bytes["mirror"],
        "stripes + parity must cost more than one copy ({} vs {})",
        bytes["ec:2/1"],
        bytes["mirror"]
    );
    assert!(
        bytes["ec:2/1"] < bytes["nway:3"],
        "erasure coding must undercut 3-way copies at equal tolerance \
         ({} vs {})",
        bytes["ec:2/1"],
        bytes["nway:3"]
    );
}

// ------------------------------------------------------------- property

/// Small-cluster configuration for the randomized sweeps.  4 MNs is the
/// smallest cluster on which every policy in `ReplPolicy::ALL`
/// validates (`ec:2/1` needs `k + m <= n_mns - 1`).
fn sweep_cfg(seed: u64, repl: ReplPolicy, faults: FaultPlan) -> SimConfig {
    let mut cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        n_cns: 4,
        n_mns: 4,
        cores_per_cn: 2,
        n_r: 2,
        ops_per_thread: 1_200,
        seed,
        dump_period_ps: us(10),
        repl,
        faults,
        ..SimConfig::default()
    };
    shrink_caches(&mut cfg);
    cfg
}

fn mn_kills(kills: &[(usize, Ps)]) -> FaultPlan {
    let mut p = FaultPlan::default();
    for &(mn, at) in kills {
        p.push_mn_crash(mn, at);
    }
    p
}

#[test]
fn prop_dump_repl_closes_the_single_mn_failure_loss_window() {
    // 200 randomized (workload seed x fault placement) cases.  The crash
    // lands anywhere from before the first dump boundary (no dumped
    // records yet — trivially safe) to many boundaries deep (dumped-only
    // records guaranteed); the dead MN is random.  With repl=mirror the
    // oracle must report zero lost words in EVERY case; with repl=single
    // on the same cases, the known loss window must reproduce at least
    // once across the sweep (per-case loss is load-dependent, the
    // aggregate is the regression pin).
    let mut lossy_without = 0u32;
    let app = by_name("ycsb").unwrap();
    check("dump-durability", 200, 0xD07_D07, |rng, knobs| {
        let seed = knob(rng, knobs, 0, 1, u32::MAX as u64);
        let mn = knob(rng, knobs, 1, 0, 3) as usize;
        // dump period is 10 us: 6..=65 us straddles ~6 dump boundaries
        let at = 6 + knob(rng, knobs, 2, 0, 59);
        let plan = mn_kills(&[(mn, us(at))]);
        let s = run_app(sweep_cfg(seed, ReplPolicy::Mirror, plan.clone()), &app);
        if !s.recovery.happened {
            return Err(format!("mn{mn}@{at}us: no recovery completed"));
        }
        if s.recovery.failed_mns != [mn] {
            return Err(format!(
                "mn{mn}@{at}us: recovered {:?}",
                s.recovery.failed_mns
            ));
        }
        if !s.recovery.consistent {
            return Err(format!(
                "mn{mn}@{at}us seed {seed}: {} lost words with repl=mirror",
                s.recovery.inconsistencies
            ));
        }
        let s0 = run_app(sweep_cfg(seed, ReplPolicy::Single, plan), &app);
        if !s0.recovery.consistent {
            lossy_without += 1;
        }
        Ok(())
    });
    assert!(
        lossy_without > 0,
        "no sweep case reproduced the repl=single loss window — the \
         property is no longer testing the durability gap it claims to"
    );
}

#[test]
fn prop_policies_are_loss_free_within_their_tolerance() {
    // nway:3 and ec:2/1 both advertise tolerance() == 2: any two MN
    // failures — even landing inside one detection window, before any
    // re-replication can restore the invariant — must lose nothing.
    // Placement guarantees at least one surviving chunk source per dead
    // bucket: nway keeps a full copy on a survivor, and any two of
    // ec's surviving holders union to the full record list (parity
    // chunks carry it whole under the union model).
    let app = by_name("ycsb").unwrap();
    for repl in [ReplPolicy::NWay(3), ReplPolicy::Ec(2, 1)] {
        assert_eq!(repl.tolerance(), 2);
        let name = format!("durability-{}", repl.name());
        check(&name, 60, 0x70C_0DE, |rng, knobs| {
            let seed = knob(rng, knobs, 0, 1, u32::MAX as u64);
            let first = knob(rng, knobs, 1, 0, 3) as usize;
            let second = (first + 1 + knob(rng, knobs, 2, 0, 2) as usize) % 4;
            let at = 6 + knob(rng, knobs, 3, 0, 59);
            // 0..8 us: straddles the 10 us detection window
            let gap_ns = knob(rng, knobs, 4, 0, 8_000);
            let plan = mn_kills(&[(first, us(at)), (second, us(at) + gap_ns * 1_000)]);
            let s = run_app(sweep_cfg(seed, repl, plan), &app);
            if !s.recovery.happened {
                return Err(format!(
                    "{}: mn{first}+mn{second}@{at}us: no recovery completed",
                    repl.name()
                ));
            }
            if !s.recovery.consistent {
                return Err(format!(
                    "{}: mn{first}+mn{second}@{at}us gap {gap_ns}ns seed {seed}: \
                     {} lost words within the advertised tolerance",
                    repl.name(),
                    s.recovery.inconsistencies
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn kills_above_the_policy_tolerance_reopen_the_loss_window() {
    // Three near-simultaneous MN deaths exceed tolerance() == 2 for
    // both nway:3 and ec:2/1.  Killing MNs 1, 2, 3 inside one detection
    // window leaves only MN 0: nway loses MN 1's bucket outright (its
    // copies live on MNs 2 and 3), and ec keeps only a single data
    // stripe of the MN 2 and MN 3 buckets.  Per-case loss is
    // load-dependent, so the pin is aggregate: across the seed sweep
    // the window must reproduce at least once per policy — and the
    // oracle must keep reporting it honestly rather than wedging.
    let app = by_name("ycsb").unwrap();
    for repl in [ReplPolicy::NWay(3), ReplPolicy::Ec(2, 1)] {
        let mut lossy = 0u32;
        for seed in 0..8u64 {
            let at = us(36);
            let plan = mn_kills(&[(1, at), (2, at + 1_000), (3, at + 2_000)]);
            let s = run_app(sweep_cfg(seed * 7 + 1, repl, plan), &app);
            assert!(
                s.recovery.happened,
                "{}: recovery must complete even above tolerance",
                repl.name()
            );
            if !s.recovery.consistent {
                lossy += 1;
            }
        }
        assert!(
            lossy > 0,
            "{}: no seed reproduced the above-tolerance loss window",
            repl.name()
        );
    }
}
