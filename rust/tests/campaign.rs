//! Tier-1 integration for the chaos campaign: the real judge (full
//! simulation + oracle verdict + sharded-vs-serial differential) must
//! be deterministic — bit-identical across reruns and worker counts —
//! and the replay spec must reproduce a case exactly.  Failure
//! *content* is not asserted here (a genuinely failing campaign case is
//! the fuzzer doing its job, surfaced by the CI campaign run); what
//! must never drift is the determinism contract.

use recxl::campaign::{judge, run_campaign_with, CampaignOpts, SeedSpec};
use recxl::cluster::{run_app, schedule_fingerprint};

fn small_opts(workers: usize) -> CampaignOpts {
    CampaignOpts {
        cases: 2,
        seed: 0xCAFE,
        workers,
        soak: false,
        max_failures: 1,
        // shrinking a real failure here would re-simulate dozens of
        // candidates; the shrinker has its own planted-judge tests
        shrink: false,
    }
}

#[test]
fn real_judge_campaign_is_worker_count_invariant() {
    let one = run_campaign_with(&small_opts(1), &judge);
    let two = run_campaign_with(&small_opts(2), &judge);
    assert_eq!(one.digest, two.digest);
    assert_eq!(one.cases.len(), two.cases.len());
    for (a, b) in one.cases.iter().zip(two.cases.iter()) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.knobs, b.knobs);
        assert_eq!(a.brief, b.brief);
        assert_eq!(a.result, b.result);
    }
}

#[test]
fn rerunning_the_same_campaign_is_bit_identical() {
    let a = run_campaign_with(&small_opts(2), &judge);
    let b = run_campaign_with(&small_opts(2), &judge);
    assert_eq!(a.digest, b.digest);
    for (x, y) in a.cases.iter().zip(b.cases.iter()) {
        assert_eq!(x.result, y.result);
    }
}

#[test]
fn replay_spec_reproduces_the_case_and_its_verdict() {
    let spec = SeedSpec {
        seed: 0xCAFE,
        index: 5,
        knobs: None,
    };
    let (case, cc) = spec.materialize();
    let first = judge(&cc);

    // the knobs route (what a shrunk reproducer replays through) must
    // land on the identical case and the identical verdict
    let pinned = SeedSpec {
        seed: 0xCAFE,
        index: 5,
        knobs: Some(case.knobs().to_vec()),
    };
    let (case2, cc2) = pinned.materialize();
    assert_eq!(case.knobs(), case2.knobs(), "knob vector is normalized");
    assert_eq!(cc.brief(), cc2.brief());
    assert_eq!(cc.cfg.faults, cc2.cfg.faults);
    assert_eq!(first, judge(&cc2));

    // and the spec string round-trips through the CLI grammar
    let parsed = SeedSpec::parse(&pinned.render()).unwrap();
    assert_eq!(parsed, pinned);
}

#[test]
fn judge_reports_the_serial_schedule_fingerprint() {
    let spec = SeedSpec {
        seed: 0xCAFE,
        index: 0,
        knobs: None,
    };
    let (_, cc) = spec.materialize();
    if let Ok(fp) = judge(&cc) {
        let stats = run_app(cc.cfg.clone(), &cc.app);
        assert_eq!(
            fp,
            schedule_fingerprint(&stats),
            "a passing judgement returns the serial fingerprint"
        );
    }
}
