//! Locality-aware shard partitioning, measured end to end: the affinity
//! scan + greedy partitioner must cut cross-shard envelope traffic on
//! the steered ycsb profile without perturbing the schedule, the
//! cross-shard ledger counters must be live exactly when sharding is,
//! and the pre-run partition must compose with mid-run MN-crash
//! re-homing (`LineTable::kill_mn`).

use recxl::prelude::*;
use recxl::proto::MsgClass;
use recxl::sim::time::Ps;
use recxl::stats::ShardingStats;

/// Paper-shaped default cluster (16 CNs x 4 cores, 16 MNs), proactive.
fn ycsb_cfg(ops: u64) -> SimConfig {
    SimConfig {
        ops_per_thread: ops,
        ..SimConfig::default()
    }
}

/// The schedule-level fingerprint slice this file cares about: simulated
/// time, event count, commits, per-class traffic.  The cross-shard
/// counters are deliberately outside it — they measure the host-side
/// partition, not the simulated system.
fn fp(s: &RunStats) -> (Ps, u64, u64, Vec<u64>) {
    (
        s.exec_time_ps,
        s.events,
        s.repl.store_commits,
        MsgClass::ALL.iter().map(|&c| s.traffic.bytes_of(c)).collect(),
    )
}

fn run(cfg: &SimConfig, shards: usize, partition: PartitionPolicy, app: &AppProfile) -> RunStats {
    let mut c = cfg.clone();
    c.shards = shards;
    c.partition = partition;
    run_app(c, app)
}

#[test]
fn locality_cuts_cross_shard_envelopes_on_ycsb_proactive() {
    // ycsb steers p_near = 0.85 of its remote traffic to a per-CN home
    // MN chosen rr-misaligned ((5c+11) mod 64), so round-robin placement
    // crosses shards on every steered access while the affinity
    // partitioner can co-locate each CN with its home MN.  The issue's
    // acceptance bar is a >= 30% envelope reduction; the steering margin
    // predicts ~2x that, so 0.7x is asserted with headroom.
    let app = by_name("ycsb").unwrap();
    let cfg = ycsb_cfg(1_500);
    for shards in [2usize, 4] {
        let rr = run(&cfg, shards, PartitionPolicy::RoundRobin, &app);
        let loc = run(&cfg, shards, PartitionPolicy::Locality, &app);
        assert_eq!(
            fp(&rr),
            fp(&loc),
            "partition policy must not change the schedule at shards={shards}"
        );
        let rr_total = rr.sharding.total_envelopes();
        let loc_total = loc.sharding.total_envelopes();
        assert!(
            rr_total > 0,
            "round-robin at shards={shards} must stage cross-shard envelopes"
        );
        assert!(
            (loc_total as f64) <= 0.7 * rr_total as f64,
            "locality must cut cross-shard envelopes by >= 30% at \
             shards={shards}: rr={rr_total} locality={loc_total}"
        );
    }
}

#[test]
fn cross_shard_ledger_counters_are_zero_without_sharding() {
    // shards=1 runs the same windowed engine, but every node lives on
    // the base shard under either policy — nothing is cross-shard.
    let app = by_name("ycsb").unwrap();
    let cfg = ycsb_cfg(800);
    for partition in PartitionPolicy::ALL {
        let s = run(&cfg, 1, partition, &app);
        assert_eq!(
            s.sharding,
            ShardingStats::default(),
            "partition={} must count nothing at shards=1",
            partition.name()
        );
    }
}

#[test]
fn sync_and_oracle_crossings_are_counted() {
    // Under round-robin at shards=2, half the CNs live off the base
    // shard: their oracle commits are buffered (counted per commit) and
    // their lock traffic lands in the sync ledger (ycsb's p_lock=0.0005
    // yields dozens of acquires at this op count).
    let app = by_name("ycsb").unwrap();
    let s = run(&ycsb_cfg(1_500), 2, PartitionPolicy::RoundRobin, &app);
    assert!(
        s.sharding.cross_shard_oracle_commits > 0,
        "off-base CNs must buffer oracle commits"
    );
    assert!(
        s.sharding.cross_shard_sync_ops > 0,
        "off-base lock traffic must land in the sync ledger"
    );
    assert!(s.sharding.total_envelopes() > 0);
}

#[test]
fn locality_composes_with_mn_crash_rehoming() {
    // The partition is fixed before the run from the pre-crash homing;
    // `LineTable::kill_mn` then re-homes the dead MN's lines mid-run.
    // The stale placement may cost envelopes but must not perturb the
    // schedule or the recovery outcome.
    let app = by_name("ycsb").unwrap();
    let sc = recxl::scenarios::by_name("mn-crash").unwrap();
    let mut cfg = SimConfig {
        n_cns: 4,
        n_mns: 4,
        ops_per_thread: 4_000,
        ..SimConfig::default()
    };
    sc.prepare(&mut cfg);
    let base = run_app(cfg.clone(), &app);
    let loc = run(&cfg, 2, PartitionPolicy::Locality, &app);
    assert_eq!(fp(&base), fp(&loc), "mn-crash must be partition-invariant");
    assert_eq!(base.recovery.failed_mns, loc.recovery.failed_mns);
    assert!(
        !loc.recovery.failed_mns.is_empty() && loc.recovery.rehomed_lines > 0,
        "the scenario must actually exercise kill_mn re-homing"
    );
}
