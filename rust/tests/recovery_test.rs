//! Crash + recovery integration tests (section V): the consistency oracle
//! must hold for every ReCXL recovery, the Table-I exchange must be
//! complete, and — the paper's motivation — plain write-back must be shown
//! to actually *lose* data on a crash.

use recxl::prelude::*;
use recxl::sim::time::us;

fn crash_cfg(protocol: Protocol, ops: u64, cn: usize, at_us: u64) -> SimConfig {
    SimConfig {
        protocol,
        ops_per_thread: ops,
        faults: FaultPlan::single_crash(cn, us(at_us)),
        ..SimConfig::default()
    }
}

#[test]
fn recxl_recovery_is_consistent_across_apps() {
    for app in ["ycsb", "ocean-cp", "bodytrack", "canneal"] {
        let s = run_app(crash_cfg(Protocol::ReCxlProactive, 6_000, 0, 35), &by_name(app).unwrap());
        assert!(s.recovery.happened, "{app}: recovery must trigger");
        assert!(
            s.recovery.consistent,
            "{app}: {} violations",
            s.recovery.inconsistencies
        );
        assert!(s.recovery.owned_lines > 0, "{app}: crashed CN owned lines");
    }
}

#[test]
fn recovery_consistent_for_all_recxl_variants() {
    for p in [Protocol::ReCxlBaseline, Protocol::ReCxlParallel, Protocol::ReCxlProactive] {
        let s = run_app(crash_cfg(p, 5_000, 0, 50), &by_name("ycsb").unwrap());
        assert!(s.recovery.happened && s.recovery.consistent, "{}", p.name());
    }
}

#[test]
fn recovery_consistent_for_any_crashed_cn() {
    for cn in [0usize, 7, 15] {
        let s = run_app(crash_cfg(Protocol::ReCxlProactive, 5_000, cn, 50), &by_name("ycsb").unwrap());
        assert!(s.recovery.consistent, "crash of CN {cn}");
    }
}

#[test]
fn recovery_consistent_across_seeds_and_crash_times() {
    for (seed, at) in [(1u64, 20u64), (77, 35), (31337, 50)] {
        let mut cfg = crash_cfg(Protocol::ReCxlProactive, 6_000, 3, at);
        cfg.seed = seed;
        let s = run_app(cfg, &by_name("ocean-ncp").unwrap());
        assert!(s.recovery.happened, "seed {seed} at {at}us");
        assert!(s.recovery.consistent, "seed {seed} at {at}us");
    }
}

#[test]
fn recovery_with_minimum_replication_factor() {
    let mut cfg = crash_cfg(Protocol::ReCxlProactive, 5_000, 0, 50);
    cfg.n_r = 2;
    let s = run_app(cfg, &by_name("ycsb").unwrap());
    assert!(s.recovery.consistent, "N_r=2 still tolerates one failure");
}

#[test]
fn recovery_uses_mn_logs_after_dumps() {
    // force frequent dumps so some of the crashed CN's updates only
    // survive in the MN-resident dumped logs
    let mut cfg = crash_cfg(Protocol::ReCxlProactive, 8_000, 0, 60);
    cfg.dump_period_ps = us(15);
    let s = run_app(cfg, &by_name("ocean-cp").unwrap());
    assert!(s.recovery.consistent);
    assert!(s.repl.dumps > 0);
}

#[test]
fn table1_message_exchange_is_complete() {
    let s = run_app(crash_cfg(Protocol::ReCxlProactive, 5_000, 0, 50), &by_name("ycsb").unwrap());
    let m = &s.recovery.messages;
    let live = 15u64; // 16 CNs - 1 failed
    let mns = 16u64;
    assert_eq!(m["Msi"], 1);
    assert_eq!(m["Interrupt"], live);
    assert_eq!(m["InterruptResp"], live);
    assert_eq!(m["InitRecov"], mns);
    assert_eq!(m["InitRecovResp"], mns);
    assert_eq!(m["RecovEnd"], live);
    assert_eq!(m["RecovEndResp"], live);
    assert!(m["FetchLatestVers"] >= 1);
    assert_eq!(m["FetchLatestVers"], m["FetchLatestVersResp"]);
}

#[test]
fn census_splits_owned_into_dirty_and_exclusive() {
    let s = run_app(crash_cfg(Protocol::ReCxlProactive, 6_000, 0, 40), &by_name("ycsb").unwrap());
    let r = &s.recovery;
    assert_eq!(r.owned_lines, r.dirty_lines + r.exclusive_lines);
    // Fig. 15 ground truth: the directory census must agree with the
    // crashed CN's cache contents for dirty lines
    assert_eq!(r.dirty_lines, r.cache_census.dirty);
}

#[test]
fn write_back_crash_loses_committed_data() {
    // the paper's motivation (section II-B): without ReCXL, the dirty
    // data in a failed CN's caches is simply gone.
    let s = run_app(crash_cfg(Protocol::WriteBack, 6_000, 0, 40), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    assert!(
        !s.recovery.consistent,
        "WB has no replicas: a crash with {} dirty lines must lose data",
        s.recovery.dirty_lines
    );
}

#[test]
fn live_nodes_make_forward_progress_after_recovery() {
    // crash early so the survivors have most of their trace left
    let s = run_app(crash_cfg(Protocol::ReCxlProactive, 8_000, 0, 25), &by_name("ycsb").unwrap());
    assert!(s.recovery.consistent);
    // 60 live cores each consume their full trace
    let live_ops: u64 = s.cores.iter().skip(4).map(|c| c.ops).sum();
    assert_eq!(live_ops, 60 * 8_000);
    assert!(s.exec_time_ps > s.recovery.completed_at);
}

#[test]
fn recovery_completes_quickly_relative_to_run() {
    let s = run_app(crash_cfg(Protocol::ReCxlProactive, 8_000, 0, 45), &by_name("bodytrack").unwrap());
    let window = s.recovery.completed_at - s.recovery.detection_at;
    assert!(
        window < recxl::sim::time::ms(5),
        "recovery took {window} ps — unexpectedly long"
    );
}

// ---- multi-failure fault plans (the FaultPlan scenario engine) ----

fn multi_cfg(faults: &str, ops: u64) -> SimConfig {
    SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: ops,
        faults: FaultPlan::parse(faults).unwrap(),
        ..SimConfig::default()
    }
}

#[test]
fn sequential_double_crash_runs_two_rounds() {
    // second failure lands well after the first round completes
    let s = run_app(multi_cfg("cn0@30us,cn8@300us", 8_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    assert_eq!(s.recovery.rounds, 2, "sequential failures = two rounds");
    assert_eq!(s.recovery.failed_cns, vec![0, 8]);
    assert!(
        s.recovery.consistent,
        "{} violations",
        s.recovery.inconsistencies
    );
}

#[test]
fn crash_during_recovery_restarts_the_round_and_covers_both() {
    // first detection at 40 us; the second CN dies 5 us into the round
    let s = run_app(multi_cfg("cn0@30us,cn3@45us", 6_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    let mut failed = s.recovery.failed_cns.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![0, 3], "restarted round must cover both");
    assert!(s.recovery.consistent);
}

#[test]
fn cm_crash_reelects_deterministically_and_recovers() {
    // CN1 dies first, electing CN0 as CM; CN0 then dies mid-round, so the
    // MSI re-elects CN2 and the round restarts covering both failures
    let s = run_app(multi_cfg("cn1@30us,cn0@44us", 6_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    let mut failed = s.recovery.failed_cns.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![0, 1]);
    assert!(s.recovery.consistent, "CM re-election must not lose data");
    assert!(
        s.recovery.messages["Msi"] >= 2,
        "the round must have been (re)started at least twice"
    );
}

#[test]
fn nr_staggered_failures_stay_consistent() {
    // the replication factor's full claim: N_r = 3 failures tolerated
    let s = run_app(
        multi_cfg("cn0@30us,cn1@44us,cn2@58us", 6_000),
        &by_name("ycsb").unwrap(),
    );
    assert!(s.recovery.happened);
    assert_eq!(s.recovery.failed_cns.len(), 3);
    assert!(
        s.recovery.consistent,
        "{} violations",
        s.recovery.inconsistencies
    );
}

// ---- MN fail-stop: re-homing + memory/directory reconstruction ----

fn mn_cfg(faults: &str, ops: u64) -> SimConfig {
    SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: ops,
        faults: FaultPlan::parse(faults).unwrap(),
        ..SimConfig::default()
    }
}

#[test]
fn mn_crash_recovers_with_state_rebuilt_from_replica_logs() {
    let s = run_app(mn_cfg("mn8@40us", 6_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened, "MN failure must trigger a round");
    assert_eq!(s.recovery.failed_mns, vec![8]);
    assert!(s.recovery.failed_cns.is_empty());
    assert!(
        s.recovery.rehomed_lines > 0,
        "lines homed on MN 8 must re-home"
    );
    // the reconstruction direction no CN-crash scenario reaches: memory
    // rebuilt at the new home from replica Logging Units (plus surviving
    // cache copies where one exists)
    assert!(
        s.recovery.rebuilt_from_caches + s.recovery.rebuilt_from_logs > 0,
        "some re-homed line must be reconstructed"
    );
    assert!(
        s.recovery.consistent,
        "{} violations",
        s.recovery.inconsistencies
    );
    // survivors finish their full traces against the re-homed lines
    assert_eq!(s.total_ops(), 64 * 6_000);
}

#[test]
fn mn_crash_recovery_is_consistent_across_apps_and_times() {
    for (app, at) in [("ycsb", 30u64), ("ocean-cp", 50), ("canneal", 40)] {
        let s = run_app(
            mn_cfg(&format!("mn3@{at}us"), 5_000),
            &by_name(app).unwrap(),
        );
        assert!(s.recovery.happened, "{app}@{at}us");
        assert!(
            s.recovery.consistent,
            "{app}@{at}us: {} violations",
            s.recovery.inconsistencies
        );
    }
}

#[test]
fn mn_crash_during_cn_recovery_restarts_and_covers_both() {
    // CN0 dies at 30 us (detected at 40 us); MN 8 dies 5 us into the
    // round — the restarted round must repair the dead CN's lines AND
    // rebuild the dead MN's, in one epoch
    let s = run_app(mn_cfg("cn0@30us,mn8@45us", 6_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    assert_eq!(s.recovery.failed_cns, vec![0]);
    assert_eq!(s.recovery.failed_mns, vec![8]);
    assert!(
        s.recovery.consistent,
        "{} violations",
        s.recovery.inconsistencies
    );
}

#[test]
fn link_degradation_slows_but_never_triggers_recovery() {
    let healthy = run_app(mn_cfg("", 5_000), &by_name("ycsb").unwrap());
    let degraded = run_app(
        mn_cfg("link:cn3@20us*8x..400us", 5_000),
        &by_name("ycsb").unwrap(),
    );
    assert!(!degraded.recovery.happened, "nothing died");
    assert_eq!(degraded.total_ops(), 64 * 5_000);
    assert!(
        degraded.exec_time_ps > healthy.exec_time_ps,
        "an 8x-degraded port must cost time: {} vs {}",
        degraded.exec_time_ps,
        healthy.exec_time_ps
    );
}

#[test]
fn survivors_complete_their_traces_after_a_double_crash() {
    let s = run_app(multi_cfg("cn0@25us,cn5@40us", 6_000), &by_name("ycsb").unwrap());
    assert!(s.recovery.consistent);
    // 14 live CNs x 4 cores each consume their full trace
    let live_ops: u64 = s
        .cores
        .iter()
        .enumerate()
        .filter(|(i, _)| i / 4 != 0 && i / 4 != 5)
        .map(|(_, c)| c.ops)
        .sum();
    assert_eq!(live_ops, 56 * 6_000);
}
