//! FaultPlan + scenario engine: parsing through the config surface, the
//! ISSUE's double-crash-at-minimum-replication integration check, the
//! named scenarios, and the randomized fault-plan property — recovery
//! completes and the oracle passes whenever concurrent failures stay
//! within the replication factor `N_r`.

use recxl::config::apply_override;
use recxl::prelude::*;
use recxl::ptest::{check, knob};
use recxl::scenarios;
use recxl::sim::time::us;

// ---------------------------------------------------------------- parsing

#[test]
fn faults_override_parses_into_the_plan() {
    let mut cfg = SimConfig::default();
    apply_override(&mut cfg, "faults", "cn0@12.5ms,cn3@20ms").unwrap();
    assert_eq!(cfg.faults.len(), 2);
    assert_eq!(cfg.faults.crashed_cns(), vec![0, 3]);
    assert_eq!(cfg.faults.events()[0].at, us(12_500));
    assert!(cfg.validate().is_ok());
}

#[test]
fn bad_fault_plans_are_rejected() {
    let mut cfg = SimConfig::default();
    assert!(apply_override(&mut cfg, "faults", "cn0").is_err(), "no time");
    assert!(apply_override(&mut cfg, "faults", "gpu2@5us").is_err(), "unknown node kind");
    assert!(
        apply_override(&mut cfg, "faults", "link:cn0@5us").is_err(),
        "link window incomplete"
    );
    // out-of-range nodes and unsorted times parse, then fail validation
    apply_override(&mut cfg, "faults", "cn99@5us").unwrap();
    assert!(cfg.validate().is_err(), "out-of-range CN");
    apply_override(&mut cfg, "faults", "mn99@5us").unwrap();
    assert!(cfg.validate().is_err(), "out-of-range MN");
    apply_override(&mut cfg, "faults", "cn0@50us,cn1@20us").unwrap();
    assert!(cfg.validate().is_err(), "unsorted times");
    apply_override(&mut cfg, "faults", "cn0@20us,cn0@50us").unwrap();
    assert!(cfg.validate().is_err(), "duplicate CN");
    apply_override(&mut cfg, "faults", "link:cn0@50us*4x..20us").unwrap();
    assert!(cfg.validate().is_err(), "inverted link window");
}

#[test]
fn mn_and_link_tokens_round_trip_through_the_config_surface() {
    // the new grammar: MN fail-stop and link-degradation windows flow
    // through --set / config files exactly like CN crashes
    let mut cfg = SimConfig::default();
    apply_override(&mut cfg, "faults", "cn0@10us, mn2@5ms, link:cn3@10us*4x..50us").unwrap();
    assert_eq!(cfg.faults.len(), 3);
    assert_eq!(cfg.faults.crashed_cns(), vec![0]);
    assert_eq!(cfg.faults.crashed_mns(), vec![2]);
    assert_eq!(cfg.faults.crash_count(), 2, "link windows are not crashes");
    assert!(cfg.validate().is_ok());
    // summary -> parse -> summary is a fixpoint for every kind
    let reparsed = FaultPlan::parse(&cfg.faults.summary()).unwrap();
    assert_eq!(reparsed, cfg.faults);
    assert_eq!(reparsed.summary(), cfg.faults.summary());
    // link node may be an MN port too
    apply_override(&mut cfg, "faults", "link:mn1@5us*2x..9us").unwrap();
    assert!(cfg.validate().is_ok());
}

#[test]
fn survivor_validation_counts_each_kind_separately() {
    // regression for the old `events.len() >= n_cns` check: non-CN events
    // must not count against the CN survivor rule
    let mut cfg = SimConfig {
        n_cns: 3,
        n_mns: 3,
        n_r: 2,
        ..SimConfig::default()
    };
    apply_override(&mut cfg, "faults", "cn0@1us,cn1@2us,mn0@3us,link:cn2@4us*2x..9us")
        .unwrap();
    assert_eq!(cfg.faults.len(), 4, "more events than CNs");
    assert!(cfg.validate().is_ok(), "{:?}", cfg.validate());
    // but each kind's own survivor rule still bites
    apply_override(&mut cfg, "faults", "cn0@1us,cn1@2us,cn2@3us").unwrap();
    assert!(cfg.validate().is_err(), "no CN survivor");
    apply_override(&mut cfg, "faults", "mn0@1us,mn1@2us,mn2@3us").unwrap();
    assert!(cfg.validate().is_err(), "no MN survivor");
}

// ------------------------------------------------------------ integration

#[test]
fn double_crash_with_minimum_replication_recovers() {
    // ISSUE acceptance: a double crash with n_r = 2 recovers and passes
    // the generalized oracle
    let cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 6_000,
        n_r: 2,
        faults: FaultPlan::parse("cn0@30us,cn5@120us").unwrap(),
        ..SimConfig::default()
    };
    let s = run_app(cfg, &by_name("ycsb").unwrap());
    assert!(s.recovery.happened);
    let mut failed = s.recovery.failed_cns.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![0, 5]);
    assert!(
        s.recovery.consistent,
        "n_r=2 must tolerate two failures: {} violations",
        s.recovery.inconsistencies
    );
}

#[test]
fn named_scenarios_run_to_completion_with_oracle_passing() {
    // ISSUE acceptance: double-crash, crash-during-recovery, and cm-crash
    // each run to completion with the generalized oracle passing
    for name in [
        "no-crash",
        "single-crash",
        "double-crash",
        "crash-during-recovery",
        "cm-crash",
        "nr-failures",
        "mn-crash",
        "link-degraded",
        "mn-crash-during-cn-recovery",
        "campaign-cascade",
        "mn-crash-after-dump",
    ] {
        let sc = scenarios::by_name(name).unwrap();
        let cfg = SimConfig {
            protocol: Protocol::ReCxlProactive,
            ops_per_thread: 6_000,
            ..SimConfig::default()
        };
        let s = scenarios::run_scenario(&sc, cfg.clone(), &by_name("ycsb").unwrap());
        scenarios::verdict(&sc, &cfg, &s).unwrap_or_else(|e| panic!("scenario {name}: {e}"));
    }
}

// --------------------------------------------------------------- property

#[test]
fn prop_random_fault_plans_recover_when_failures_le_nr() {
    // ISSUE acceptance: the property holds over >= 100 randomized plans.
    // Small cluster so 100 full simulations stay fast; n_r = 2, so plans
    // inject 0..=2 failures at random CNs and random (sorted) times.
    check("fault-plan-recovery", 100, 0xFA17, |rng, knobs| {
        let n_cns = 6usize;
        let n_r = 2usize;
        let mut pos = 0;
        let mut draw = |rng: &mut recxl::sim::Pcg, knobs: &mut Vec<u64>, lo: u64, hi: u64| {
            let v = knob(rng, knobs, pos, lo, hi);
            pos += 1;
            v
        };
        let n_failures = draw(rng, knobs, 0, n_r as u64) as usize;
        let mut t_us = 15 + draw(rng, knobs, 0, 25);
        let mut plan = FaultPlan::default();
        let mut used = vec![false; n_cns];
        for _ in 0..n_failures {
            let mut cn = draw(rng, knobs, 0, n_cns as u64 - 1) as usize;
            while used[cn] {
                cn = (cn + 1) % n_cns;
            }
            used[cn] = true;
            plan.push_crash(cn, us(t_us));
            t_us += 3 + draw(rng, knobs, 0, 40);
        }
        let seed = draw(rng, knobs, 0, u32::MAX as u64);
        plan.validate(n_cns, 4)
            .map_err(|e| format!("generated plan invalid: {e}"))?;
        let cfg = SimConfig {
            protocol: Protocol::ReCxlProactive,
            n_cns,
            n_mns: 4,
            cores_per_cn: 2,
            n_r,
            ops_per_thread: 1_200,
            seed,
            faults: plan,
            ..SimConfig::default()
        };
        let s = run_app(cfg, &by_name("ycsb").unwrap());
        if n_failures == 0 {
            if s.recovery.happened {
                return Err("fault-free plan triggered recovery".into());
            }
            return Ok(());
        }
        if !s.recovery.happened {
            return Err(format!("{n_failures} failures but no recovery completed"));
        }
        if s.recovery.failed_cns.len() != n_failures {
            return Err(format!(
                "recovered {} of {n_failures} failures",
                s.recovery.failed_cns.len()
            ));
        }
        if !s.recovery.consistent {
            return Err(format!(
                "oracle: {} violations with {n_failures} <= n_r failures",
                s.recovery.inconsistencies
            ));
        }
        Ok(())
    });
}
