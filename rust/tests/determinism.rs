//! Determinism under the hot-path overhauls (§Perf): the calendar event
//! queue, the pooled zero-alloc message delivery, the line-interned slab
//! state (PR 3: directory/cache/MSHR/oracle/log-unit slabs + ordered
//! recovery broadcasts), the trace memo, and the counter-array stats
//! must leave the simulated schedule — and therefore every reported
//! number — bit-identical run over run, on every named fault scenario,
//! and across `run_grid` thread counts.
//!
//! Note the rerun comparisons below also pin the trace memo: the first
//! run generates every block cold, the second replays them from the
//! process-wide cache — any divergence would change the fingerprint.

use recxl::figures::run_grid;
use recxl::prelude::*;
use recxl::proto::MsgClass;
use recxl::sim::time::Ps;

/// A small cluster keeps the 2x-run sweep cheap; scenarios scale their
/// fault plans to it (`Scenario::plan` takes the config).
fn scen_cfg(ops: u64) -> SimConfig {
    SimConfig {
        n_cns: 4,
        n_mns: 4,
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: ops,
        ..SimConfig::default()
    }
}

/// Everything that must match bit-for-bit between two runs: simulated
/// time, event count, per-class traffic (totals + the 50 us timeline —
/// `MsgClass::ALL` now includes the dump-replication class), commits,
/// and the recovery outcome including the dump-durability counters
/// (`rebuilt_dumps`, `rereplicated_chunks`).
#[allow(clippy::type_complexity)]
fn fingerprint(
    s: &RunStats,
) -> (
    Ps,
    u64,
    Vec<u64>,
    Vec<u64>,
    Vec<Vec<u64>>,
    u64,
    Vec<usize>,
    Vec<usize>,
    (u64, u64, u64),
) {
    (
        s.exec_time_ps,
        s.events,
        MsgClass::ALL.iter().map(|&c| s.traffic.bytes_of(c)).collect(),
        MsgClass::ALL
            .iter()
            .map(|&c| s.traffic.messages_of(c))
            .collect(),
        MsgClass::ALL
            .iter()
            .map(|&c| s.traffic.timeline_bytes(c))
            .collect(),
        s.repl.store_commits,
        s.recovery.failed_cns.clone(),
        s.recovery.failed_mns.clone(),
        (
            s.recovery.rehomed_lines,
            s.recovery.rebuilt_dumps,
            s.recovery.rereplicated_chunks,
        ),
    )
}

#[test]
fn fixed_seed_is_bit_identical_on_every_named_scenario() {
    let app = by_name("ycsb").unwrap();
    for sc in recxl::scenarios::all() {
        let mut cfg = scen_cfg(6_000);
        sc.prepare(&mut cfg);
        let a = run_app(cfg.clone(), &app);
        let b = run_app(cfg, &app);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "scenario {} must be bit-identical across reruns",
            sc.name
        );
    }
}

#[test]
fn run_grid_is_identical_across_thread_counts() {
    let app = by_name("ycsb").unwrap();
    let mut points = Vec::new();
    for name in [
        "no-crash",
        "double-crash",
        "mn-crash",
        "link-degraded",
        "mn-crash-after-dump",
    ] {
        let sc = recxl::scenarios::by_name(name).unwrap();
        let mut cfg = scen_cfg(4_000);
        sc.prepare(&mut cfg);
        points.push((cfg, app.clone()));
    }
    let seq = run_grid(points.clone(), false);
    let par = run_grid(points, true);
    for (i, (s, p)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(
            fingerprint(s),
            fingerprint(p),
            "grid point {i} must not depend on host parallelism"
        );
    }
}

#[test]
fn shard_counts_share_one_schedule_on_every_named_scenario() {
    // The windowed engine runs every shard count — including 1 — through
    // the same conservative-lookahead schedule, so the fingerprint must
    // not depend on `shards` for any registered fault scenario.
    let app = by_name("ycsb").unwrap();
    for sc in recxl::scenarios::all() {
        let mut cfg = scen_cfg(4_000);
        sc.prepare(&mut cfg);
        let base = run_app(cfg.clone(), &app);
        for shards in [2, 4] {
            let mut c = cfg.clone();
            c.shards = shards;
            let s = run_app(c, &app);
            assert_eq!(
                fingerprint(&base),
                fingerprint(&s),
                "scenario {} must be bit-identical at shards={shards}",
                sc.name
            );
        }
    }
}

#[test]
fn partition_policies_share_one_schedule_on_every_named_scenario() {
    // The locality partitioner only moves nodes between shard queues.
    // Every barrier/merge resolution key (staged messages, sync ledger,
    // oracle commits, event node keys) is partition-independent, so the
    // fingerprint must be pinned across the full partition x shard-count
    // matrix on every registered fault scenario.  The cross-shard ledger
    // counters are partition-dependent by design and deliberately not
    // part of the fingerprint.
    let app = by_name("ycsb").unwrap();
    for sc in recxl::scenarios::all() {
        let mut cfg = scen_cfg(3_000);
        sc.prepare(&mut cfg);
        let base = run_app(cfg.clone(), &app);
        for partition in PartitionPolicy::ALL {
            for shards in [1usize, 2, 4] {
                if partition == PartitionPolicy::RoundRobin && shards == 1 {
                    continue; // the base run itself
                }
                let mut c = cfg.clone();
                c.partition = partition;
                c.shards = shards;
                let s = run_app(c, &app);
                assert_eq!(
                    fingerprint(&base),
                    fingerprint(&s),
                    "scenario {} must be bit-identical at partition={} shards={shards}",
                    sc.name,
                    partition.name()
                );
            }
        }
    }
}

#[test]
fn shard_counts_agree_on_dumped_log_durability_paths() {
    // mn-crash-after-dump exercises the dumped-log rebuild; every
    // replication policy's dump path must be shard-invariant (the
    // rebuild itself runs in the serial phase, but the dumps and the
    // re-replication it depends on run windowed).
    let app = by_name("ycsb").unwrap();
    let sc = recxl::scenarios::by_name("mn-crash-after-dump").unwrap();
    for repl in ReplPolicy::ALL {
        let mut cfg = scen_cfg(4_000);
        sc.prepare(&mut cfg);
        cfg.repl = repl;
        let base = run_app(cfg.clone(), &app);
        for shards in [2, 4] {
            let mut c = cfg.clone();
            c.shards = shards;
            let s = run_app(c, &app);
            assert_eq!(
                fingerprint(&base),
                fingerprint(&s),
                "mn-crash-after-dump (repl={}) must be bit-identical at shards={shards}",
                repl.name()
            );
        }
    }
}

#[test]
fn mirror_policy_is_bit_identical_to_the_legacy_dump_repl_flag() {
    // `repl=mirror` lifts the hard-wired 2-copy dump path of PR 5 into
    // the policy layer; the refactor must be invisible — the fingerprint
    // under the modern knob must equal the one under the legacy
    // `dump_repl=1` alias (which maps onto Mirror), dump rebuild
    // included.
    let app = by_name("ycsb").unwrap();
    let sc = recxl::scenarios::by_name("mn-crash-after-dump").unwrap();
    let mut modern = scen_cfg(4_000);
    sc.prepare(&mut modern);
    recxl::config::apply_override(&mut modern, "repl", "mirror").unwrap();
    let mut legacy = scen_cfg(4_000);
    sc.prepare(&mut legacy);
    recxl::config::apply_override(&mut legacy, "dump_repl", "1").unwrap();
    assert_eq!(modern.repl, ReplPolicy::Mirror);
    assert_eq!(legacy.repl, ReplPolicy::Mirror);
    let a = run_app(modern, &app);
    let b = run_app(legacy, &app);
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "repl=mirror must reproduce the legacy dump_repl=1 run exactly"
    );
}

#[test]
fn sharded_grid_points_match_their_serial_twins() {
    // run_grid schedules narrow and wide points in separate phases and
    // clamps per-point shards to host parallelism; neither the phase
    // split nor the clamp may perturb results (fingerprints are
    // shard-count-invariant), so mixing shard widths in one parallel
    // grid must match the sequential twins.
    let app = by_name("ycsb").unwrap();
    let mut points = Vec::new();
    for shards in [1, 2, 4] {
        for partition in PartitionPolicy::ALL {
            let mut cfg = scen_cfg(3_000);
            cfg.shards = shards;
            cfg.partition = partition;
            points.push((cfg, app.clone()));
        }
    }
    let seq = run_grid(points.clone(), false);
    let par = run_grid(points, true);
    for (i, (s, p)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(
            fingerprint(s),
            fingerprint(p),
            "sharded grid point {i} must not depend on host parallelism"
        );
        assert_eq!(
            fingerprint(s),
            fingerprint(&seq[0]),
            "grid point {i} must match the shards=1 twin"
        );
    }
}

#[test]
fn closed_loop_is_the_default_and_pins_the_historical_schedule() {
    // `arrival=closed` must be the default AND a no-op: the arrival gate
    // stays inert (no release times, no zipf skew, no latency samples),
    // so an explicit `--set arrival=closed` run is bit-identical to an
    // untouched one — which is what keeps every pre-arrival fingerprint
    // valid.  An open-loop override must genuinely change the schedule:
    // the zipf key skew alone reshapes the access stream.
    let app = by_name("ycsb").unwrap();
    let base = run_app(scen_cfg(4_000), &app);
    assert_eq!(base.latency.ops.count, 0, "closed loop must not sample");
    let mut explicit = scen_cfg(4_000);
    recxl::config::apply_override(&mut explicit, "arrival", "closed").unwrap();
    let e = run_app(explicit, &app);
    assert_eq!(
        fingerprint(&base),
        fingerprint(&e),
        "explicit arrival=closed must equal the default run exactly"
    );
    let mut open = scen_cfg(4_000);
    recxl::config::apply_override(&mut open, "arrival", "poisson:8").unwrap();
    let o = run_app(open, &app);
    assert_ne!(
        fingerprint(&base),
        fingerprint(&o),
        "an open-loop run must actually change the schedule"
    );
    assert!(o.latency.ops.count > 0, "open loop must sample latencies");
}

#[test]
fn message_pool_recycles_in_steady_state() {
    let s = run_app(scen_cfg(6_000), &by_name("ycsb").unwrap());
    assert!(
        s.msg_pool_allocated > 0,
        "a nonempty run must deliver messages"
    );
    assert!(
        s.msg_pool_recycled > s.msg_pool_allocated,
        "steady-state delivery must reuse pooled boxes, not allocate: \
         allocated {} vs recycled {}",
        s.msg_pool_allocated,
        s.msg_pool_recycled
    );
}

#[test]
fn seeds_still_differentiate_schedules() {
    // the pooled/bucketed fast paths must not have frozen the seed out of
    // the schedule
    let app = by_name("ycsb").unwrap();
    let a = run_app(scen_cfg(4_000), &app);
    let mut cfg = scen_cfg(4_000);
    cfg.seed = 0xDEAD_BEEF;
    let b = run_app(cfg, &app);
    assert_ne!(a.exec_time_ps, b.exec_time_ps);
}
