//! Cross-layer tests: the AOT-compiled Pallas artifacts executed through
//! PJRT must agree bit-for-bit with the Rust reference implementations.
//! This pins the whole L1 (Pallas) <-> L3 (Rust) contract.
//!
//! Requires `make artifacts` (skipped, loudly, when artifacts are absent —
//! e.g. in a fresh checkout before the Python toolchain ran) and a build
//! with `--features pjrt` (the whole file is compiled out otherwise).

#![cfg(feature = "pjrt")]

use recxl::recovery::logquery;
use recxl::runtime::Runtime;
use recxl::sim::Pcg;
use recxl::workloads::{profiles, tracegen, TraceSource};

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn trace_gen_artifact_matches_rust_generator() {
    let Some(rt) = runtime() else { return };
    for (seed, base, thread) in [(42u32, 0u32, 0usize), (7, 4096, 17), (0xDEAD, 123 * 4096, 63)] {
        for app in ["ycsb", "ocean-cp", "raytrace"] {
            let params = profiles::by_name(app).unwrap().to_params(thread, 4);
            let pjrt = rt.trace_block(seed as i32, base as i32, &params).unwrap();
            let rust = tracegen::gen_block(seed, base, &params);
            assert_eq!(pjrt.len(), rust.len());
            assert_eq!(pjrt, rust, "app {app} seed {seed} base {base}");
        }
    }
}

#[test]
fn pjrt_trace_source_streams_blocks() {
    let Some(rt) = runtime() else { return };
    let mut src = recxl::runtime::PjrtTraceSource::new(rt);
    let params = profiles::ycsb().to_params(3, 4);
    let a = src.block(9, 0, &params);
    let b = src.block(9, 4096, &params);
    assert_eq!(a.len(), tracegen::N_OPS);
    assert_ne!(a, b);
    assert_eq!(src.blocks_generated, 2);
    assert_eq!(src.name(), "pjrt");
}

#[test]
fn latest_version_artifact_matches_rust_query() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg::new(0xA0B1, 7);
    for _ in 0..5 {
        let n = 64 + rng.below(512) as usize;
        let nq = 1 + rng.below(64) as usize;
        let space = 1 + rng.below(40) as i32;
        let la: Vec<i32> = (0..n).map(|_| (rng.below(space as u64)) as i32).collect();
        let ts: Vec<i32> = (0..n).map(|_| rng.below(1 << 14) as i32).collect();
        let valid: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let val: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32).collect();
        let q: Vec<i32> = (0..nq).map(|_| rng.below(space as u64 + 4) as i32).collect();

        // the Rust reference operates on padded arrays like the kernel
        let pad = |xs: &[i32], len: usize, fill: i32| {
            let mut v = vec![fill; len];
            v[..xs.len()].copy_from_slice(xs);
            v
        };
        let want = logquery::latest_versions(
            &q,
            &pad(&la, logquery::N_LOG, -1),
            &pad(&ts, logquery::N_LOG, 0),
            &pad(&valid, logquery::N_LOG, 0),
            &pad(&val, logquery::N_LOG, 0),
        );
        let got = rt.latest_versions(&q, &la, &ts, &valid, &val).unwrap();
        assert_eq!(got, want);
    }
}

#[test]
fn simulation_identical_under_pjrt_and_rust_sources() {
    use recxl::cluster::Cluster;
    use recxl::config::SimConfig;
    use recxl::workloads::RustTraceSource;

    let Some(rt) = runtime() else { return };
    let cfg = SimConfig {
        n_cns: 4,
        n_mns: 4,
        ops_per_thread: 1_500,
        ..SimConfig::default()
    };
    let app = profiles::ycsb();
    let a = Cluster::with_source(cfg.clone(), &app, Box::new(RustTraceSource)).run();
    let b = Cluster::with_source(
        cfg,
        &app,
        Box::new(recxl::runtime::PjrtTraceSource::new(rt)),
    )
    .run();
    assert_eq!(a.exec_time_ps, b.exec_time_ps, "trace sources must be equivalent");
    assert_eq!(a.repl.repls_sent, b.repl.repls_sent);
    assert_eq!(a.events, b.events);
}
