//! Open-loop arrival + tail-latency contract tests (the figure-19 /
//! `cn-crash-under-load` surface):
//!
//! * fault-free, the measured issue rate tracks the offered load — the
//!   arrival process actually paces the run;
//! * a CN crash under load blows out the p999 while the median holds —
//!   the recovery pause costs the tail, not the middle of the
//!   distribution (the PR's acceptance shape);
//! * the latency histogram is shard-invariant: per-op samples ride the
//!   shard shells and fold exactly once, so sharded runs report the
//!   same percentiles bit for bit.

use recxl::cluster::run_app;
use recxl::config::{ArrivalProcess, SimConfig};
use recxl::prelude::*;

fn open_cfg(rate: f64, ops: u64) -> SimConfig {
    SimConfig {
        n_cns: 4,
        n_mns: 4,
        protocol: Protocol::ReCxlProactive,
        arrival: ArrivalProcess::Poisson { rate },
        ops_per_thread: ops,
        ..SimConfig::default()
    }
}

#[test]
fn offered_load_matches_measured_issue_rate_fault_free() {
    // 4 ops/us offered per CN at 4 cores/CN = 1 us mean gap per thread,
    // far above the mean service time, so the run is release-bound: the
    // measured rate (executed ops over simulated time) must land on the
    // offered rate.  15% headroom covers the drain tail and the
    // warm-up/rounding of the dyadic sampler.
    let app = by_name("ycsb").unwrap();
    let rate = 4.0;
    let cfg = open_cfg(rate, 3_000);
    let n_cns = cfg.n_cns as f64;
    let s = run_app(cfg, &app);
    assert!(s.latency.ops.count > 0, "open loop must sample latencies");
    let offered_per_us = rate * n_cns;
    let measured_per_us = s.total_ops() as f64 / (s.exec_time_ps as f64 / 1e6);
    let err = (measured_per_us - offered_per_us).abs() / offered_per_us;
    assert!(
        err < 0.15,
        "offered {offered_per_us:.2} ops/us vs measured {measured_per_us:.2} ops/us \
         (err {err:.3})"
    );
}

#[test]
fn crash_under_load_blows_out_the_tail_but_not_the_median() {
    // The acceptance shape: under `cn-crash-under-load`'s arrival stream,
    // the crashed run's p999 sits strictly above its fault-free twin
    // (ops released into the recovery pause queue behind it) while p50
    // stays within 2x (the bulk of the run never sees the pause).
    let app = by_name("ycsb").unwrap();
    let sc = recxl::scenarios::by_name("cn-crash-under-load").unwrap();
    let mut crashed = SimConfig {
        n_cns: 4,
        n_mns: 4,
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 4_000,
        ..SimConfig::default()
    };
    sc.prepare(&mut crashed);
    assert!(crashed.arrival.is_open(), "the scenario must open the loop");
    let mut clean = crashed.clone();
    clean.faults = Default::default();
    let c = run_app(crashed.clone(), &app);
    let f = run_app(clean, &app);
    assert!(c.recovery.happened && c.recovery.consistent);
    assert!(f.latency.ops.count > 0 && c.latency.ops.count > 0);
    assert!(
        c.latency.ops.p999() > f.latency.ops.p999(),
        "crashed p999 {} must exceed fault-free p999 {}",
        c.latency.ops.p999(),
        f.latency.ops.p999()
    );
    assert!(
        c.latency.ops.p50() <= 2 * f.latency.ops.p50().max(1),
        "crashed p50 {} must stay within 2x of fault-free p50 {}",
        c.latency.ops.p50(),
        f.latency.ops.p50()
    );
    // one recovery-duration sample per completed round
    assert_eq!(c.latency.recovery.count, c.recovery.rounds);
    assert_eq!(f.latency.recovery.count, 0);
}

#[test]
fn latency_histogram_is_shard_invariant() {
    // The schedule fingerprint is shard-invariant (tests/determinism.rs);
    // the latency histogram rides outside the fingerprint, so pin it
    // separately: every shard count must report the identical histogram
    // — same buckets, same sum, same max — under the crash scenario.
    let app = by_name("ycsb").unwrap();
    let sc = recxl::scenarios::by_name("cn-crash-under-load").unwrap();
    let mut cfg = SimConfig {
        n_cns: 4,
        n_mns: 4,
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 3_000,
        ..SimConfig::default()
    };
    sc.prepare(&mut cfg);
    let base = run_app(cfg.clone(), &app);
    for shards in [2usize, 4] {
        let mut c = cfg.clone();
        c.shards = shards;
        let s = run_app(c, &app);
        for (name, a, b) in [
            ("ops", &base.latency.ops, &s.latency.ops),
            ("recovery", &base.latency.recovery, &s.latency.recovery),
        ] {
            assert_eq!(a.count, b.count, "{name} count at shards={shards}");
            assert_eq!(a.sum_ps, b.sum_ps, "{name} sum at shards={shards}");
            assert_eq!(a.max_ps, b.max_ps, "{name} max at shards={shards}");
            assert_eq!(
                a.bucket_counts(),
                b.bucket_counts(),
                "{name} buckets at shards={shards}"
            );
        }
        assert_eq!(base.latency.ops.p999(), s.latency.ops.p999());
    }
}
