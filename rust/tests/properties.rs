//! Property-based tests (ptest, the in-repo proptest-lite) over the
//! coordinator's pure invariants: replica routing, logical-timestamp
//! ordering under adversarial reordering, SB coalescing, version
//! selection, and fabric FIFO-ness.

use recxl::cpu::StoreBuffer;
use recxl::mem::{Addr, Line, LineId};
use recxl::proto::ReqId;
use recxl::ptest::{check, knob};
use recxl::recovery::{select_version, VersionList};
use recxl::recxl::logunit::{LoggingUnit, LogRecord, PendingRepl};
use recxl::recxl::{dump_owner, replica_window, replicas};
use recxl::sim::Pcg;

fn line(i: u64) -> Line {
    Addr(0x8000_0000 | ((i as u32 & 0xFFFFF) << 6)).line()
}

fn lid(i: u64) -> LineId {
    LineId(i as u32 & 0xFFFFF)
}

#[test]
fn prop_replica_routing() {
    check("replica-routing", 256, 0xA11CE, |rng, knobs| {
        let n_cns = knob(rng, knobs, 0, 4, 32) as usize;
        let n_r = knob(rng, knobs, 1, 2, 4).min(n_cns as u64 - 1) as usize;
        let l = line(knob(rng, knobs, 2, 0, 1 << 20));
        let req = knob(rng, knobs, 3, 0, n_cns as u64 - 1) as usize;
        let reps = replicas(l, req, n_cns, n_r);
        if reps.len() != n_r {
            return Err(format!("got {} replicas, wanted {n_r}", reps.len()));
        }
        if reps.contains(&req) {
            return Err("requester must never be its own replica".into());
        }
        let mut sorted = reps.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != n_r {
            return Err("replicas must be distinct".into());
        }
        let window = replica_window(l, n_cns, n_r);
        if !reps.iter().all(|c| window.contains(c)) {
            return Err("replicas must lie in the line's window".into());
        }
        let owner = dump_owner(l, req, n_cns, n_r);
        if !reps.contains(&owner) {
            return Err("dump owner must be a replica".into());
        }
        Ok(())
    });
}

#[test]
fn prop_logical_ts_ordering_survives_reordering() {
    // VALs delivered in a random (adversarial) order must still push
    // entries to the DRAM log in timestamp order per source CN.
    check("logical-ts-order", 128, 0xBEEF, |rng, knobs| {
        let n = knob(rng, knobs, 0, 2, 40) as usize;
        let n_srcs = knob(rng, knobs, 1, 1, 4) as usize;
        let mut lu = LoggingUnit::new(5, 16, 10_000, 100_000);
        // issue REPLs in ts order per src, interleaved round-robin
        let mut vals = Vec::new();
        let mut next_ts = vec![0u64; n_srcs];
        for i in 0..n {
            let src = i % n_srcs;
            let req = ReqId { cn: src, core: 0 };
            let ts = {
                next_ts[src] += 1;
                next_ts[src]
            };
            let l = line(i as u64);
            lu.repl(
                0,
                PendingRepl {
                    req,
                    line: l,
                    lid: lid(i as u64),
                    mask: 1,
                    words: [ts as u32; 16],
                    repl_seq: ts,
                },
            );
            vals.push((req, l, ts));
        }
        // adversarial delivery order
        let mut order: Vec<usize> = (0..vals.len()).collect();
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        for &i in &order {
            let (req, l, ts) = vals[i];
            lu.val(0, req, l, ts, ts);
        }
        // all entries must have reached DRAM, in per-src ts order
        let mut per_src_last = vec![0u64; n_srcs];
        let mut total = 0;
        for i in 0..n {
            let vl = &lu.fetch_latest_vers(&[(line(i as u64), lid(i as u64))])[0];
            total += vl.versions.len();
        }
        if total != n {
            return Err(format!("{total} of {n} entries reached the log"));
        }
        // verify global order via a scan: query each line, its single
        // entry's ts must be >= everything earlier from the same src
        // (DRAM log is append-ordered; fetch preserves it)
        for i in 0..n {
            let vl = &lu.fetch_latest_vers(&[(line(i as u64), lid(i as u64))])[0];
            let r = vl.versions[0];
            let src = r.req.cn;
            if r.ts < per_src_last[src] {
                return Err(format!("src {src}: ts {} after {}", r.ts, per_src_last[src]));
            }
            per_src_last[src] = r.ts;
        }
        Ok(())
    });
}

#[test]
fn prop_sb_coalescing_invariants() {
    check("sb-coalescing", 256, 0xC0A1, |rng, knobs| {
        let n = knob(rng, knobs, 0, 1, 100) as usize;
        let n_lines = knob(rng, knobs, 1, 1, 8);
        let mut sb = StoreBuffer::new(72, true);
        let mut deposits = 0;
        let mut last_write = std::collections::HashMap::new();
        for i in 0..n {
            let li = rng.below(n_lines);
            let l = line(li);
            let word = (rng.below(16)) as u8;
            let v = i as u32;
            match sb.deposit(l, lid(li), true, word, v, 0) {
                recxl::cpu::Deposit::Full => break,
                _ => {
                    deposits += 1;
                    last_write.insert((l, word), v);
                }
            }
        }
        if sb.len() > deposits {
            return Err("entries cannot exceed deposits".into());
        }
        // TSO forwarding returns the youngest value
        for ((l, w), v) in &last_write {
            match sb.forward(*l, *w) {
                Some(got) if got == *v => {}
                other => return Err(format!("forward({l:?},{w}) = {other:?}, want {v}")),
            }
        }
        // proactive candidates: remote, not sent, never the open tail
        let cands = sb.proactive_repl_candidates();
        if cands.contains(&(sb.len().saturating_sub(1))) && sb.len() > 0 {
            return Err("open tail must not be a candidate under coalescing".into());
        }
        Ok(())
    });
}

#[test]
fn prop_select_version_picks_global_latest() {
    // scatter a committed update sequence across N_r ordered logs with
    // random truncation of the newest suffix (crash skew); the selected
    // value must be the newest entry present in ANY log.
    check("select-version", 200, 0x5E1E, |rng, knobs| {
        let n_updates = knob(rng, knobs, 0, 1, 12);
        let n_logs = knob(rng, knobs, 1, 1, 4) as usize;
        let failed = 3usize;
        let l = line(9);
        let mk = |seq: u64| LogRecord {
            req: ReqId { cn: failed, core: 0 },
            line: l,
            word: 0,
            value: 100 + seq as u32,
            ts: seq,
            repl_seq: seq,
            valid: true,
        };
        // each log sees a prefix of the updates (>= 1), latest-first
        let mut lists = Vec::new();
        let mut newest_anywhere = 0;
        for _ in 0..n_logs {
            let seen = 1 + rng.below(n_updates);
            newest_anywhere = newest_anywhere.max(seen);
            let versions: Vec<LogRecord> = (1..=seen).rev().map(mk).collect();
            lists.push(VersionList { line: l, versions });
        }
        let refs: Vec<&VersionList> = lists.iter().collect();
        let got = select_version(l, failed, &refs, &[]).ok_or("no selection")?;
        if got.words[0] != 100 + newest_anywhere as u32 {
            return Err(format!(
                "selected {} but newest anywhere is {}",
                got.words[0],
                100 + newest_anywhere as u32
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_fifo_per_route() {
    // non-reorderable messages between the same endpoints arrive in send
    // order (the directory depends on this for acks)
    check("fabric-fifo", 128, 0xF1F0, |rng, knobs| {
        let n = knob(rng, knobs, 0, 2, 50) as usize;
        let cfg = recxl::config::SimConfig::default();
        let mut fabric = recxl::fabric::Fabric::new(&cfg);
        let mut traffic = recxl::stats::TrafficStats::default();
        let mut last = 0;
        let mut t = 0;
        for _ in 0..n {
            t += rng.below(500);
            let msg = recxl::proto::Message {
                src: recxl::proto::NodeId::Cn(0),
                dst: recxl::proto::NodeId::Mn(0),
                kind: recxl::proto::MsgKind::RdS {
                    line: line(rng.below(100)),
                    req: ReqId { cn: 0, core: 0 },
                },
            };
            match fabric.send(t, &msg, &mut traffic) {
                recxl::fabric::Delivery::At(at) => {
                    if at < last {
                        return Err(format!("arrival {at} before previous {last}"));
                    }
                    last = at;
                }
                _ => return Err("dropped without viral".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_trace_blocks_consistent_with_any_base() {
    // counter-based generation: any block window must equal the matching
    // slice of any other overlapping window
    check("trace-random-access", 64, 0x7ACE, |rng, knobs| {
        let seed = knob(rng, knobs, 0, 0, u32::MAX as u64) as u32;
        let base = (knob(rng, knobs, 1, 0, 1000) as u32) * 512;
        let params = recxl::workloads::profiles::ycsb().to_params(rng.below(64) as usize, 4);
        let a = recxl::workloads::tracegen::gen_block(seed, base, &params);
        let b = recxl::workloads::tracegen::gen_block(seed, base + 512, &params);
        if a[512..] != b[..a.len() - 512] {
            return Err("overlapping windows disagree".into());
        }
        Ok(())
    });
}
