//! Whole-cluster integration tests: every protocol configuration runs to
//! completion on a small cluster and upholds the cross-protocol
//! performance and accounting invariants the paper's evaluation rests on.

use recxl::prelude::*;
use recxl::proto::MsgClass;

fn small(protocol: Protocol) -> SimConfig {
    SimConfig {
        protocol,
        ops_per_thread: 3_000,
        ..SimConfig::default()
    }
}

fn run(protocol: Protocol, app: &str) -> RunStats {
    run_app(small(protocol), &by_name(app).unwrap())
}

#[test]
fn all_protocols_complete_on_all_apps() {
    for app in all_apps() {
        for p in Protocol::ALL {
            let cfg = SimConfig {
                protocol: p,
                ops_per_thread: 800,
                ..SimConfig::default()
            };
            let s = run_app(cfg, &app);
            assert!(s.exec_time_ps > 0, "{}/{}", app.name, p.name());
            assert_eq!(
                s.total_ops(),
                64 * 800,
                "{}/{} must consume the whole trace",
                app.name,
                p.name()
            );
        }
    }
}

#[test]
fn determinism_same_seed_same_result() {
    let a = run(Protocol::ReCxlProactive, "ycsb");
    let b = run(Protocol::ReCxlProactive, "ycsb");
    assert_eq!(a.exec_time_ps, b.exec_time_ps);
    assert_eq!(a.repl.repls_sent, b.repl.repls_sent);
    assert_eq!(a.events, b.events);
    assert_eq!(
        a.traffic.bytes_of(MsgClass::Replication),
        b.traffic.bytes_of(MsgClass::Replication)
    );
}

#[test]
fn different_seed_different_schedule() {
    let a = run(Protocol::WriteBack, "ycsb");
    let mut cfg = small(Protocol::WriteBack);
    cfg.seed = 999;
    let b = run_app(cfg, &by_name("ycsb").unwrap());
    assert_ne!(a.exec_time_ps, b.exec_time_ps);
}

#[test]
fn fig2_shape_wt_much_slower_than_wb() {
    // the motivation figure: WT with TSO serialization is prohibitively
    // expensive on write-intensive workloads
    for app in ["ocean-ncp", "ycsb"] {
        let wb = run(Protocol::WriteBack, app);
        let wt = run(Protocol::WriteThrough, app);
        let ratio = wt.exec_time_ps as f64 / wb.exec_time_ps as f64;
        assert!(ratio > 2.0, "{app}: WT/WB = {ratio:.2}, expected >> 1");
    }
}

#[test]
fn fig10_shape_protocol_ordering() {
    // Fig. 10's shape on a write-heavy app: WB is the floor, WT the
    // ceiling, and the ReCXL variants sit between, with earlier
    // replication start never losing to later start by more than noise.
    //
    // Recalibrated from the PR-1 version per the EXPERIMENTS.md protocol:
    // the container still has no toolchain, so this is the *analytic*
    // calibration — each retained inequality follows from the commit
    // rules (WB waits on a strict subset of proactive's conditions; WT
    // serializes a 500 ns persist per store under TSO; baseline starts
    // replication strictly later than parallel/proactive) with headroom
    // for queueing noise.  The strict `proactive < parallel` claim was
    // dropped: proactive's early REPLs seal SB entries against
    // coalescing, so on some coalescing-heavy apps it trades REPL count
    // against head latency — the paper's claim is about loaded SBs, and
    // the first measured pass should tighten this to the observed ratio.
    let app = "ocean-cp";
    let wb = run(Protocol::WriteBack, app).exec_time_ps as f64;
    let pro = run(Protocol::ReCxlProactive, app).exec_time_ps as f64;
    let par = run(Protocol::ReCxlParallel, app).exec_time_ps as f64;
    let base = run(Protocol::ReCxlBaseline, app).exec_time_ps as f64;
    let wt = run(Protocol::WriteThrough, app).exec_time_ps as f64;
    assert!(wb <= pro * 1.01, "WB is the lower bound: wb={wb} pro={pro}");
    assert!(
        pro <= base * 1.10,
        "proactive must not lose to baseline: pro={pro} base={base}"
    );
    assert!(
        par <= base * 1.10,
        "parallel must not lose to baseline: par={par} base={base}"
    );
    assert!(base < wt, "every ReCXL variant beats write-through: base={base} wt={wt}");
    assert!(pro < wt && par < wt, "pro={pro} par={par} wt={wt}");
}

#[test]
fn wb_generates_no_replication_traffic() {
    let s = run(Protocol::WriteBack, "ycsb");
    assert_eq!(s.traffic.bytes_of(MsgClass::Replication), 0);
    assert_eq!(s.repl.repls_sent, 0);
    assert_eq!(s.repl.vals_sent, 0);
}

#[test]
fn recxl_vals_match_commits_times_replicas() {
    let s = run(Protocol::ReCxlProactive, "ycsb");
    assert!(s.repl.repls_sent > 0);
    // every replicated group commits exactly once and VALs all N_r replicas
    assert_eq!(s.repl.vals_sent, s.repl.repls_sent * 3);
}

#[test]
fn baseline_sends_all_repls_at_head() {
    // Fig. 6a: baseline's replication transaction starts at the SB head
    let s = run(Protocol::ReCxlBaseline, "ycsb");
    assert_eq!(s.repl.repls_at_head, s.repl.repls_sent);
}

#[test]
fn proactive_sends_most_repls_early() {
    // Fig. 6c / Fig. 11: under a loaded SB, REPLs leave before the store
    // reaches the head.  Recalibrated from the PR-1 version (see
    // EXPERIMENTS.md): with no toolchain in this container the measured
    // tightening pass is still pending, so the primary assertion is the
    // *relative* shape — baseline by construction sends 100% at the head
    // (asserted separately above), proactive must send strictly fewer —
    // plus an analytic bound: remote-store commit latency (~2x RTT) is
    // hundreds of retire cycles, so the SB backs up and most entries gain
    // a successor (which triggers the early REPL) before reaching the
    // head.  The first measured pass should tighten 0.75 toward the
    // paper's < 0.5.
    let s = run(Protocol::ReCxlProactive, "ycsb");
    assert!(s.repl.repls_sent > 0);
    assert!(
        s.repl.repls_at_head < s.repl.repls_sent,
        "some REPLs must leave before the head"
    );
    assert!(
        s.repl.frac_repls_at_head() < 0.75,
        "frac at head = {}",
        s.repl.frac_repls_at_head()
    );
}

#[test]
fn coalescing_reduces_repl_count() {
    let with = run(Protocol::ReCxlProactive, "ocean-cp");
    let mut cfg = small(Protocol::ReCxlProactive);
    cfg.coalescing = false;
    let without = run_app(cfg, &by_name("ocean-cp").unwrap());
    assert!(
        with.repl.repls_sent < without.repl.repls_sent,
        "coalescing must merge store groups: {} vs {}",
        with.repl.repls_sent,
        without.repl.repls_sent
    );
    assert!(with.repl.stores_coalesced > 0);
}

#[test]
fn log_dump_compresses_and_stays_small() {
    let mut cfg = small(Protocol::ReCxlProactive);
    cfg.ops_per_thread = 6_000;
    cfg.dump_period_ps = recxl::sim::time::us(30); // force several dumps
    let s = run_app(cfg, &by_name("ocean-ncp").unwrap());
    assert!(s.repl.dumps > 0, "dumps must have run");
    let cf = s.repl.compression_factor();
    // the in-repo LZSS size model (recxl::logcomp) has no entropy coder,
    // so it undershoots real gzip (paper: ~5.8x); structured logs must
    // still compress clearly
    assert!(cf > 1.2, "level-9 LZSS on structured logs compresses (got {cf:.2}x)");
    // Fig. 14: dump bandwidth is a small fraction of access bandwidth
    let access = s.class_gbps(MsgClass::CxlAccess);
    let dump = s.class_gbps(MsgClass::LogDump);
    assert!(
        dump < access / 5.0,
        "dump {dump:.2} GB/s must be small vs access {access:.2} GB/s"
    );
}

#[test]
fn link_bandwidth_sensitivity_direction() {
    // Fig. 16: starving the links hurts ReCXL on bandwidth-hungry apps
    let fast = run(Protocol::ReCxlProactive, "ycsb").exec_time_ps;
    let mut cfg = small(Protocol::ReCxlProactive);
    cfg.link_bw_gbps = 20;
    let slow = run_app(cfg, &by_name("ycsb").unwrap()).exec_time_ps;
    assert!(slow > fast, "20 GB/s must be slower than 160 GB/s");
}

#[test]
fn replication_factor_monotonicity() {
    // Fig. 17: higher N_r costs (weakly) more on write-heavy apps
    let app = by_name("ocean-ncp").unwrap();
    let mut times = Vec::new();
    for nr in [2usize, 4] {
        let mut cfg = small(Protocol::ReCxlProactive);
        cfg.n_r = nr;
        times.push(run_app(cfg, &app).exec_time_ps);
    }
    assert!(times[1] >= times[0], "N_r=4 {} vs N_r=2 {}", times[1], times[0]);
}

#[test]
fn smaller_cluster_runs_and_validates() {
    let mut cfg = small(Protocol::ReCxlProactive);
    cfg.n_cns = 4;
    cfg.n_mns = 4;
    let s = run_app(cfg, &by_name("barnes").unwrap());
    assert_eq!(s.total_ops(), 16 * 3_000);
}

#[test]
fn fence_drains_sb_before_locks() {
    // lock-dense app: lock waits exist and the run completes (fence
    // semantics don't deadlock)
    let s = run(Protocol::ReCxlBaseline, "fluidanimate");
    let lock_wait: u64 = s.cores.iter().map(|c| c.lock_wait_ps).sum();
    assert!(lock_wait > 0, "fluidanimate must contend on locks");
}
