//! YCSB over the CXL-DSM cluster: the paper's key-value workload
//! (section VI — 500 K x 1 KB records, 80/20 reads/writes, uniform, all
//! accesses to CXL memory) served under each protocol, with
//! throughput/latency-style reporting.
//!
//! ```sh
//! cargo run --release --example ycsb_cluster
//! ```

use recxl::prelude::*;
use recxl::proto::MsgClass;
use recxl::sim::time::fmt_ps;

fn main() {
    let app = by_name("ycsb").unwrap();
    let base = SimConfig {
        ops_per_thread: 20_000,
        ..SimConfig::default()
    };

    println!(
        "YCSB on {} CNs x {} cores ({} ops/thread, {}% reads):",
        base.n_cns,
        base.cores_per_cn,
        base.ops_per_thread,
        (app.p_load / (app.p_load + app.p_store) * 100.0).round()
    );
    println!(
        "{:<18} {:>12} {:>14} {:>14} {:>12}",
        "protocol", "exec", "ops/s (sim)", "CXL GB/s", "vs WB"
    );

    let mut wb_time = 0u64;
    for p in Protocol::ALL {
        let cfg = SimConfig {
            protocol: p,
            ..base.clone()
        };
        let s = run_app(cfg, &app);
        if p == Protocol::WriteBack {
            wb_time = s.exec_time_ps;
        }
        let mops = s.total_ops() as f64 / (s.exec_time_ps as f64 / 1e12);
        println!(
            "{:<18} {:>12} {:>13.1}M {:>14.1} {:>11.2}x",
            p.name(),
            fmt_ps(s.exec_time_ps),
            mops / 1e6,
            s.class_gbps(MsgClass::CxlAccess) + s.class_gbps(MsgClass::Replication),
            s.exec_time_ps as f64 / wb_time as f64,
        );
    }
    println!(
        "\n(paper, Fig. 14: YCSB drives ~110 GB/s of CXL access traffic; \
         Fig. 10: ReCXL-proactive ~1.3x over WB on average)"
    );
}
