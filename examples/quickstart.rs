//! Quickstart: simulate one application under ReCXL-proactive on the
//! paper's default 16-CN / 16-MN cluster and print the headline numbers.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use recxl::prelude::*;
use recxl::proto::MsgClass;
use recxl::sim::time::fmt_ps;

fn main() {
    let cfg = SimConfig {
        ops_per_thread: 20_000,
        ..SimConfig::default()
    };
    let app = by_name("bodytrack").unwrap();

    println!("ReCXL quickstart: {} under {}", app.name, cfg.protocol.name());
    let stats = run_app(cfg.clone(), &app);
    println!("  exec time            : {}", fmt_ps(stats.exec_time_ps));
    println!("  ops executed         : {}", stats.total_ops());
    println!("  remote stores        : {}", stats.total_remote_stores());
    println!("  REPL transactions    : {}", stats.repl.repls_sent);
    println!(
        "  CXL bandwidth        : {:.1} GB/s access + {:.1} GB/s replication",
        stats.class_gbps(MsgClass::CxlAccess),
        stats.class_gbps(MsgClass::Replication),
    );

    // how much does fault tolerance cost? (the paper's headline question)
    let slow = slowdown_vs_wb(&cfg, &app, Protocol::ReCxlProactive);
    println!("  slowdown vs plain WB : {slow:.2}x (paper: ~1.30x average)");
    assert!(slow < 2.0, "proactive should stay well under 2x");
}
