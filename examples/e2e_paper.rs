//! End-to-end driver: the full system on the paper's workload suite,
//! reproducing the headline result — *"ReCXL enables fault-tolerant
//! execution with only a ~30% slowdown over the same platform with no
//! fault-tolerance support"* (abstract / section VII-A) — plus a crash +
//! recovery pass proving the fault-tolerance actually works.
//!
//! The trace stream comes from the AOT-compiled Pallas artifact through
//! PJRT when `artifacts/` exists (run `make artifacts`), exercising all
//! three layers end to end; otherwise the bit-identical Rust generator.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_paper
//! ```

use recxl::cluster::Cluster;
use recxl::prelude::*;
use recxl::report::gmean;
use recxl::runtime::{PjrtTraceSource, Runtime};
use recxl::workloads::RustTraceSource;

fn run_with_best_source(cfg: SimConfig, app: &AppProfile, use_pjrt: bool) -> RunStats {
    if use_pjrt {
        match Runtime::load(&cfg.artifacts_dir) {
            Ok(rt) => {
                return Cluster::with_source(cfg, app, Box::new(PjrtTraceSource::new(rt))).run()
            }
            Err(e) => eprintln!("(pjrt unavailable: {e:#}; using Rust trace source)"),
        }
    }
    Cluster::with_source(cfg, app, Box::new(RustTraceSource)).run()
}

fn main() {
    let ops = 10_000u64;
    let apps = all_apps();
    let pjrt_available = Runtime::load("artifacts").is_ok();
    println!(
        "e2e: {} apps x (WB, ReCXL-proactive), {} ops/thread, trace source: {}",
        apps.len(),
        ops,
        if pjrt_available { "PJRT artifact (L1 Pallas kernel)" } else { "Rust fallback" }
    );

    let mut ratios = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        // PJRT execution is exercised on the first app; the remaining
        // sweep uses the (bit-identical) Rust source for speed.
        let use_pjrt = pjrt_available && i == 0;
        let wb = run_with_best_source(
            SimConfig {
                protocol: Protocol::WriteBack,
                ops_per_thread: ops,
                ..SimConfig::default()
            },
            app,
            use_pjrt,
        );
        let pro = run_with_best_source(
            SimConfig {
                protocol: Protocol::ReCxlProactive,
                ops_per_thread: ops,
                ..SimConfig::default()
            },
            app,
            use_pjrt,
        );
        let r = pro.exec_time_ps as f64 / wb.exec_time_ps as f64;
        ratios.push(r);
        println!("  {:<14} proactive/WB = {r:.3}", app.name);
    }
    let g = gmean(&ratios);
    println!("\nHEADLINE: ReCXL-proactive gmean slowdown over WB = {g:.3}x");
    println!("          paper reports ~1.30x on its SST testbed");
    assert!(g > 1.0 && g < 2.0, "headline shape must hold");

    // fault tolerance must actually tolerate faults — including a second
    // CN dying while the first recovery round is still running
    println!("\ncrash + recovery check (CN0 fails mid-run, CN8 mid-recovery)...");
    let s = run_app(
        SimConfig {
            protocol: Protocol::ReCxlProactive,
            ops_per_thread: ops,
            faults: FaultPlan::parse("cn0@120us,cn8@135us").unwrap(),
            ..SimConfig::default()
        },
        &by_name("ycsb").unwrap(),
    );
    assert!(s.recovery.happened && s.recovery.consistent);
    assert_eq!(s.recovery.failed_cns.len(), 2, "both failures covered");
    println!(
        "recovered {} owned lines across {} round(s), consistent = {}",
        s.recovery.owned_lines, s.recovery.rounds, s.recovery.consistent
    );
    println!("\nE2E OK");
}
