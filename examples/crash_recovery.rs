//! Crash + recovery walkthrough (sections III-B, V): run YCSB under
//! ReCXL-proactive, fail CN 0 mid-run, let the Table-I protocol repair
//! directory + memory, and verify against the consistency oracle.
//!
//! ```sh
//! cargo run --release --example crash_recovery
//! ```

use recxl::prelude::*;
use recxl::sim::time::{fmt_ps, us};

fn main() {
    let app = by_name("ycsb").unwrap();
    let cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 20_000,
        crash: Some(CrashSpec { cn: 0, at: us(250) }),
        ..SimConfig::default()
    };

    println!(
        "running {} with a fail-stop crash of CN0 at {}",
        app.name,
        fmt_ps(cfg.crash.unwrap().at)
    );
    let s = run_app(cfg, &app);
    let r = &s.recovery;
    assert!(r.happened, "crash must have triggered recovery");

    println!("\n-- failure detection (section V-A) --");
    println!("  Viral_Status set at {}", fmt_ps(r.detection_at));

    println!("\n-- directory census (Algorithm 1 / Fig. 15) --");
    println!(
        "  lines owned by CN0 : {} ({} dirty + {} exclusive-clean)",
        r.owned_lines, r.dirty_lines, r.exclusive_lines
    );
    println!("  sharer entries scrubbed : {}", r.shared_lines);

    println!("\n-- log-based repair (Algorithm 2) --");
    println!(
        "  recovered from replica Logging Units : {}",
        r.recovered_from_logs
    );
    println!(
        "  recovered from MN-resident dumps     : {}",
        r.recovered_from_mn_logs
    );

    println!("\n-- Table I message exchange --");
    let mut msgs: Vec<_> = r.messages.iter().collect();
    msgs.sort();
    for (name, count) in msgs {
        println!("  {name:<22} x{count}");
    }

    println!(
        "\nrecovery window: {} -> {} ({})",
        fmt_ps(r.detection_at),
        fmt_ps(r.completed_at),
        fmt_ps(r.completed_at - r.detection_at)
    );
    println!(
        "consistency oracle: {} ({} violations)",
        if r.consistent { "CONSISTENT" } else { "INCONSISTENT" },
        r.inconsistencies
    );
    assert!(r.consistent, "recovery must restore a consistent state");
    println!("\nOK: application state recovered; live nodes resumed.");
}
