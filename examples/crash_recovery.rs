//! Crash + recovery walkthrough (sections III-B, V), scenario-driven:
//! pick any scenario from the registry (default `crash-during-recovery`),
//! run YCSB under ReCXL-proactive through its fault plan, let the Table-I
//! protocol repair directory + memory — across however many rounds the
//! plan needs — and verify against the consistency oracle.
//!
//! ```sh
//! cargo run --release --example crash_recovery [SCENARIO]
//! cargo run --release --example crash_recovery cm-crash
//! ```

use recxl::prelude::*;
use recxl::scenarios;
use recxl::sim::time::fmt_ps;

fn main() {
    let which = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "crash-during-recovery".to_string());
    let sc = scenarios::by_name(&which).unwrap_or_else(|| {
        eprintln!("unknown scenario '{which}'; available:");
        for s in scenarios::all() {
            eprintln!("  {}", s.name);
        }
        std::process::exit(2);
    });

    let app = by_name("ycsb").unwrap();
    let cfg = SimConfig {
        protocol: Protocol::ReCxlProactive,
        ops_per_thread: 20_000,
        ..SimConfig::default()
    };

    println!(
        "scenario {} on {}: fault plan [{}]",
        sc.name,
        app.name,
        sc.plan(&cfg).summary()
    );
    let s = scenarios::run_scenario(&sc, cfg.clone(), &app);
    let r = &s.recovery;
    if sc.plan(&cfg).is_empty() {
        assert!(!r.happened, "fault-free scenario must not recover");
        println!("no faults injected; recovery machinery stayed idle. OK.");
        return;
    }
    assert!(r.happened, "fault plan must have triggered recovery");

    println!("\n-- failure detection (section V-A) --");
    println!("  first Viral_Status set at {}", fmt_ps(r.detection_at));
    println!(
        "  failures recovered: {:?} over {} round(s)",
        r.failed_cns, r.rounds
    );

    println!("\n-- directory census (Algorithm 1 / Fig. 15) --");
    println!(
        "  lines owned by failed CNs : {} ({} dirty + {} exclusive-clean)",
        r.owned_lines, r.dirty_lines, r.exclusive_lines
    );
    println!("  sharer entries scrubbed : {}", r.shared_lines);

    println!("\n-- log-based repair (Algorithm 2) --");
    println!(
        "  recovered from replica Logging Units : {}",
        r.recovered_from_logs
    );
    println!(
        "  recovered from MN-resident dumps     : {}",
        r.recovered_from_mn_logs
    );

    println!("\n-- Table I message exchange --");
    let mut msgs: Vec<_> = r.messages.iter().collect();
    msgs.sort();
    for (name, count) in msgs {
        println!("  {name:<22} x{count}");
    }

    println!(
        "\nrecovery window: {} -> {} ({})",
        fmt_ps(r.detection_at),
        fmt_ps(r.completed_at),
        fmt_ps(r.completed_at - r.detection_at)
    );
    println!(
        "consistency oracle: {} ({} violations)",
        if r.consistent { "CONSISTENT" } else { "INCONSISTENT" },
        r.inconsistencies
    );
    scenarios::verdict(&sc, &cfg, &s).expect("scenario contract must hold");
    println!("\nOK: application state recovered; live nodes resumed.");
}
