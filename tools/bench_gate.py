#!/usr/bin/env python3
"""Hot-path throughput regression gate (EXPERIMENTS.md §Perf).

Compares a freshly produced BENCH_hotpath.json against the committed
baseline (BENCH_baseline.json at the repo root) and fails when
`full_sim_events_per_sec` regresses by more than the threshold.

Usage:
    python3 tools/bench_gate.py <fresh.json> <baseline.json> [--max-regress 0.20]
        [--key full_sim_events_per_sec]

`--key` selects which metric is gated (default the hot-path throughput),
so the same gate covers other tracked reports — e.g.
`--key frontier_mirror_dump_repl_bytes` against BENCH_repl_frontier.json
(for byte-count metrics pair it with a tight --max-regress in *both*
directions once a baseline exists; the gate itself only floors).

Skips (exit 0, loudly) when:
  * the baseline is missing or marked `pending_first_measurement` — the
    gate arms itself the first time a measured baseline is committed;
  * the quick-mode flags of the two reports differ (quick and full runs
    must never be naively compared — §Perf rule 3);
  * the baseline exists but lacks the gated `--key` (an older-schema
    baseline must not fail the first run of a newly tracked metric).
"""

import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def metric(report, name):
    return report.get("metrics", {}).get(name)


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    max_regress = 0.20
    if "--max-regress" in argv:
        max_regress = float(argv[argv.index("--max-regress") + 1])
    name = "full_sim_events_per_sec"
    if "--key" in argv:
        name = argv[argv.index("--key") + 1]

    fresh = load(argv[1])
    base = load(argv[2])
    if fresh is None:
        print(f"gate: FAIL — fresh report {argv[1]} missing")
        return 1
    if base is None:
        print(f"gate: SKIP — no committed baseline at {argv[2]}; "
              "commit CI's BENCH_hotpath artifact as the baseline to arm the gate")
        return 0
    if metric(base, "pending_first_measurement"):
        print("gate: SKIP — baseline is a placeholder awaiting the first "
              "measured run (see EXPERIMENTS.md §Perf); commit a real "
              "BENCH_hotpath.json to arm the gate")
        return 0
    if metric(fresh, "quick") != metric(base, "quick"):
        print("gate: SKIP — quick-mode mismatch between fresh and baseline "
              f"({metric(fresh, 'quick')} vs {metric(base, 'quick')}); "
              "quick and full runs are not comparable")
        return 0

    f, b = metric(fresh, name), metric(base, name)
    if b is None:
        print(f"gate: SKIP — baseline {argv[2]} lacks {name}; "
              "commit a report with the new schema to arm this key")
        return 0
    if not f or not b:
        print(f"gate: FAIL — {name} missing (fresh={f}, baseline={b})")
        return 1
    ratio = f / b
    verdict = "OK" if ratio >= 1.0 - max_regress else "FAIL"
    print(f"gate: {verdict} — {name}: fresh {f:.3e} vs baseline {b:.3e} "
          f"(ratio {ratio:.3f}, floor {1.0 - max_regress:.2f})")
    return 0 if verdict == "OK" else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
