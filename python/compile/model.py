"""Layer-2 JAX model: the exported compute-graph entry points.

ReCXL is a systems paper — its "model" is not a neural network but the two
compute hot-spots of the reproduction's simulation pipeline, composed from
the Layer-1 Pallas kernels:

* ``trace_block``   — per-thread synthetic workload-trace synthesis
  (feeds the trace-driven core models; called on the Rust simulation path
  through PJRT every time a core drains its trace buffer);
* ``latest_versions`` — bulk ``FetchLatestVers`` log query used by the
  recovery path (Algorithm 2) for large query batches.

Both are jitted and AOT-lowered once by ``aot.py``; Python never runs at
simulation time.
"""

import jax

from .kernels import latest_version as lv
from .kernels import trace_gen as tg

# Re-exported geometry (the Rust runtime asserts these against the
# artifact manifest).
N_OPS = tg.N_OPS
NUM_PARAMS = tg.NUM_PARAMS
N_LOG = lv.N_LOG
Q = lv.Q


def trace_block(seed, base, params):
    """(int32[1], int32[1], int32[16]) -> (int32[N_OPS],) * 3."""
    return tg.trace_block(seed, base, params)


def latest_versions(q_addr, log_addr, log_ts, log_valid, log_val):
    """(int32[Q], int32[N_LOG] * 4) -> (int32[Q], int32[Q])."""
    return lv.latest_versions(q_addr, log_addr, log_ts, log_valid, log_val)


def lower_trace_block():
    import jax.numpy as jnp

    s1 = jax.ShapeDtypeStruct((1,), jnp.int32)
    sp = jax.ShapeDtypeStruct((NUM_PARAMS,), jnp.int32)
    return jax.jit(trace_block).lower(s1, s1, sp)


def lower_latest_versions():
    import jax.numpy as jnp

    sq = jax.ShapeDtypeStruct((Q,), jnp.int32)
    sn = jax.ShapeDtypeStruct((N_LOG,), jnp.int32)
    return jax.jit(latest_versions).lower(sq, sn, sn, sn, sn)
