"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite asserts the kernels against
(exact integer equality), and the specification the bit-identical Rust
fallback (rust/src/workloads/tracegen.rs, rust/src/recovery/logquery.rs)
implements.
"""

import jax.numpy as jnp
from jax import lax

from . import latest_version as lv
from . import trace_gen as tg


def trace_block_ref(seed, base, params):
    """Reference for kernels.trace_gen.trace_block (same signature)."""
    g = base[0].astype(jnp.uint32) + lax.iota(jnp.uint32, tg.N_OPS)
    op, addr, extra = tg.gen_fields(g, seed[0].astype(jnp.uint32), params)
    to_i32 = lambda x: lax.bitcast_convert_type(x, jnp.int32)
    return to_i32(op), to_i32(addr), to_i32(extra)


def latest_versions_ref(q_addr, log_addr, log_ts, log_valid, log_val):
    """Reference for kernels.latest_version.latest_versions."""
    n = log_addr.shape[0]
    idx = lax.iota(jnp.int32, n)
    mask = (q_addr[:, None] == log_addr[None, :]) & (log_valid[None, :] != 0)
    key = jnp.where(mask, log_ts[None, :] * lv.N_LOG + idx[None, :], -1)
    best = jnp.max(key, axis=1)
    ai = jnp.argmax(key, axis=1)
    val = jnp.where(best >= 0, jnp.take(log_val, ai), 0)
    return best, val
