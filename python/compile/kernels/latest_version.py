"""Layer-1 Pallas kernel: bulk latest-version log query (recovery hot-spot).

ReCXL recovery (Algorithm 2, section V-D) scans a replica's DRAM log and, for
every line address the directory controller requested via
``FetchLatestVers``, returns the most recent logged update.  The scan is a
masked arg-max over (queries x log entries) — a natural tiled reduction.

For each query address q and log of N entries, the kernel computes::

    key(q)  = max over i of { ts[i] * N_LOG + i  if addr[i] == q and valid[i] }
    val(q)  = log value at the maximizing entry
    (key = -1 if no entry matches)

``ts * N_LOG + i`` makes keys unique (ties broken toward the later log
index), so accumulation across tiles is a plain max-merge.  Logical
timestamps must satisfy ts < 2^31 / N_LOG; the Logging Unit's 7-bit design
(Fig. 5) is far below that, and the Rust caller re-bases timestamps per
query batch.

Geometry: all Q=256 queries stay resident in VMEM; the log streams through
in NB=512-entry tiles (grid = N_LOG / NB).  The (Q, NB) compare tile is
256x512 int32 = 512 KB of VPU work per step — comfortably inside VMEM
(DESIGN.md section 7).  ``interpret=True`` for CPU-PJRT execution.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

N_LOG = 4096  # log entries per exported call (caller pads / batches)
NB = 512      # log entries per grid step
Q = 256       # query addresses per exported call


def _kernel(qa_ref, la_ref, ts_ref, valid_ref, val_ref, key_out, val_out):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        key_out[...] = jnp.full((Q,), -1, jnp.int32)
        val_out[...] = jnp.zeros((Q,), jnp.int32)

    qa = qa_ref[...]          # (Q,)
    la = la_ref[...]          # (NB,)
    ts = ts_ref[...]          # (NB,)
    valid = valid_ref[...]    # (NB,)
    lval = val_ref[...]       # (NB,)

    idx = j * NB + lax.iota(jnp.int32, NB)
    mask = (qa[:, None] == la[None, :]) & (valid[None, :] != 0)
    key = jnp.where(mask, ts[None, :] * N_LOG + idx[None, :], -1)  # (Q, NB)
    tile_key = jnp.max(key, axis=1)                                # (Q,)
    ai = jnp.argmax(key, axis=1)                                   # (Q,)
    tile_val = jnp.take(lval, ai)

    cur = key_out[...]
    better = tile_key > cur
    key_out[...] = jnp.where(better, tile_key, cur)
    val_out[...] = jnp.where(better, tile_val, val_out[...])


def latest_versions(q_addr, log_addr, log_ts, log_valid, log_val):
    """q_addr: int32[Q]; log_*: int32[N_LOG].

    Returns (key, val): int32[Q] each.  key = ts * N_LOG + log_index of the
    latest valid matching entry, or -1; val = its logged word value.
    """
    out = jax.ShapeDtypeStruct((Q,), jnp.int32)
    full_q = pl.BlockSpec((Q,), lambda j: (0,))
    tile = pl.BlockSpec((NB,), lambda j: (j,))
    return pl.pallas_call(
        _kernel,
        grid=(N_LOG // NB,),
        in_specs=[full_q, tile, tile, tile, tile],
        out_specs=[full_q, full_q],
        out_shape=[out, out],
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls
    )(q_addr, log_addr, log_ts, log_valid, log_val)
