"""Layer-1 Pallas kernel: synthetic workload-trace synthesis.

The paper (ReCXL, CS.DC 2026) drives its SST simulation with Pin traces of
PARSEC / SPLASH-2 / YCSB.  Pin traces are unavailable here, so the
reproduction synthesizes statistically equivalent per-thread access streams
(see DESIGN.md section 2).  Producing those streams is the compute hot-spot
of trace-driven simulation, so it is implemented as a Pallas kernel:
a counter-based PRNG (splitmix32-style mixing, pure uint32 ops) maps a block
of global op indices plus an app-profile parameter vector to
``(op_code, addr, extra)`` triples.

Counter-based generation means every op is a pure function of
``(seed, thread, global_index)`` — random access into the trace, no carried
state, an embarrassingly parallel grid.  The Rust coordinator executes the
AOT-lowered HLO of this kernel through PJRT on its simulation path
(``rust/src/runtime``), with a bit-identical Rust fallback
(``rust/src/workloads/tracegen.rs``) cross-checked in integration tests.

Parameter vector layout (int32[16]) — kept in sync with
``rust/src/workloads/profiles.rs``::

    0  thread_id
    1  p_load     cumulative op threshold, 16-bit fixed point
    2  p_store    cumulative (p_load + store fraction)
    3  p_lock     cumulative (p_store + lock fraction)
    4  (reserved for barrier; barriers are inserted deterministically by
       the Rust workload layer so that all threads agree on arrival counts)
    5  p_remote   16-bit: probability a load/store targets shared CXL memory
    6  shared_lines_log2   shared footprint, in 64 B lines (power of two)
    7  private_lines_log2  per-thread private footprint (<= 18)
    8  p_seq      16-bit: probability a store belongs to a sequential run
    9  run_len_log2        length of sequential runs, in ops
    10 p_hot      16-bit: probability a random access hits the hot subset
    11 hot_lines_log2      hot-subset size, in lines
    12 cs_len     critical-section length carried in lock ops' ``extra``
    13 p_near     16-bit: probability a remote access is steered to the
       thread's affine memory-node target (0 = no steering, the historical
       stream)
    14 near_lo    low-6-bit line residue the steered accesses pin — after
       the line-interleave this residue selects the home memory node
    15 zipf       nonzero = zipfian key skew: random accesses draw from a
       dyadic zipf(s=1) over the shared footprint (each power-of-two
       octave of ranks carries equal mass) instead of the hot/uniform
       split.  0 keeps the stream bit-identical to the historical
       generator — the open-loop arrival workloads set it, ``arrival=closed``
       never does.

Op codes: 0 = compute, 1 = load, 2 = store, 3 = lock-acquire
(``extra = lock_id << 8 | cs_len``; the core model releases the lock after
``cs_len`` ops).  Addresses: bit 31 set = remote (shared CXL) —
``1<<31 | line<<6 | word<<2``; clear = CN-local —
``thread<<24 | line<<6 | word<<2``.

TPU notes (DESIGN.md section 7): integer hash + select trees are VPU work; the
block is 512 ops (one (4,128) tile's worth); ``interpret=True`` is required
for CPU-PJRT execution.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Block/grid geometry. N_OPS ops per exported call, BLOCK ops per grid step.
N_OPS = 4096
BLOCK = 512
NUM_PARAMS = 16

_U = jnp.uint32


def mix32(x):
    """splitmix32-style finalizer over uint32 (wrapping arithmetic).

    Must stay bit-identical to ``mix32`` in rust/src/workloads/tracegen.rs.
    """
    x = x + _U(0x9E3779B9)
    x = x ^ (x >> _U(16))
    x = x * _U(0x21F0AAAD)
    x = x ^ (x >> _U(15))
    x = x * _U(0x735A2D97)
    x = x ^ (x >> _U(15))
    return x


def gen_fields(g, seed, params):
    """Pure-uint32 field derivation for global op indices ``g`` (uint32[...]).

    Shared between the Pallas kernel body and the jnp reference oracle so a
    mismatch can only come from the Pallas plumbing, not the math.
    Returns (op, addr, extra) as uint32 arrays.
    """
    p = params.astype(jnp.uint32)
    t = p[0]
    h0 = mix32(seed + g * _U(0x85EBCA6B) + t * _U(0xC2B2AE35))
    r0 = mix32(h0 ^ _U(0x68E31DA4))
    r1 = mix32(h0 ^ _U(0xB5297A4D))
    r2 = mix32(h0 ^ _U(0x1B56C4E9))
    r3 = mix32(h0 ^ _U(0x7FEB352D))

    # --- op selection (16-bit cumulative thresholds) ---
    u_op = r0 >> _U(16)
    is_load = u_op < p[1]
    is_store = (~is_load) & (u_op < p[2])
    is_lock = (~is_load) & (~is_store) & (u_op < p[3])
    op = jnp.where(
        is_load, _U(1), jnp.where(is_store, _U(2), jnp.where(is_lock, _U(3), _U(0)))
    )

    # --- address derivation (meaningful for loads/stores; harmless otherwise)
    remote = (r1 & _U(0xFFFF)) < p[5]
    shared_mask = (_U(1) << p[6]) - _U(1)
    hot_mask = (_U(1) << p[11]) - _U(1)
    priv_mask = (_U(1) << p[7]) - _U(1)

    # Sequential-run structure: ops in the same run of 2^run_len_log2
    # consecutive indices share a line and walk its words — the coalescing
    # structure the SB sees (ReCXL section IV-D.5).
    seq = ((r1 >> _U(16)) & _U(0xFFFF)) < p[8]
    g_run = g >> p[9].astype(jnp.uint32)
    ls_full = mix32(g_run * _U(0x9E3779B1) + t * _U(0x632BE59B))
    line_seq = ls_full & shared_mask
    hot = (r2 >> _U(16)) < p[10]
    # Zipfian key skew (p[15] != 0, the open-loop service workload): a
    # dyadic zipf(s=1) draw — octave k uniform over the shared_log2
    # levels (multiply-shift on r2's high 16 bits), rank uniform within
    # the octave from r2's low bits.  Each octave carries equal mass,
    # which is exactly the zipf(1) octave property.  p[15] = 0 keeps the
    # stream bit-identical to the pre-zipf generator.
    k = ((r2 >> _U(16)) * p[6]) >> _U(16)
    kmask = (_U(1) << k) - _U(1)
    line_zipf = (kmask + (r2 & kmask)) & shared_mask
    line_rand = jnp.where(
        p[15] != _U(0), line_zipf, jnp.where(hot, r2 & hot_mask, r2 & shared_mask)
    )
    line_sh = jnp.where(seq, line_seq, line_rand)
    # Near-memory steering (p[13]/p[14]): a steered access pins the line's
    # low 6 bits — and with them its home memory node after interleave —
    # to p[14].  Sequential accesses draw per *run* (from the run hash, so
    # a run never splits across lines); random accesses draw per op from
    # r3's free high bits.  p[13] = 0 keeps the stream bit-identical to
    # the pre-steering generator.
    near_seq = (mix32(ls_full ^ _U(0x27D4EB2F)) >> _U(16)) < p[13]
    near_rand = (r3 >> _U(16)) < p[13]
    near = jnp.where(seq, near_seq, near_rand)
    steered = ((line_sh & ~_U(63)) | (p[14] & _U(63))) & shared_mask
    line_sh = jnp.where(near, steered, line_sh)
    word = jnp.where(seq, g & _U(15), r3 & _U(15))
    raddr = _U(0x80000000) | (line_sh << _U(6)) | (word << _U(2))

    line_lo = r2 & priv_mask
    laddr = (t << _U(24)) | (line_lo << _U(6)) | (word << _U(2))
    addr = jnp.where(remote, raddr, laddr)
    addr = jnp.where(op == _U(0), _U(0), addr)
    addr = jnp.where(op == _U(3), _U(0), addr)

    # --- extra: lock id + critical-section length for lock ops ---
    lock_id = r3 & _U(63)
    extra = jnp.where(op == _U(3), (lock_id << _U(8)) | p[12], _U(0))
    return op, addr, extra


def arrival_e_q16(g, seed, thread):
    """Q16 "dyadic exponential" inter-arrival draw for global op index ``g``.

    Mirrors ``arrival_e_q16`` in rust/src/workloads/tracegen.rs bit for
    bit: ``E = (1 + clz(r)) - frac(r)`` over a uniform nonzero uint32
    ``r`` — clz is the geometric octave (the exponent of ``-log2 u``),
    frac the Q16 linear remainder of the normalized mantissa.  Exactly
    ``E[E] = 1.5 * 2^16``; integer-only so no libm ulp can diverge the
    two implementations.  The ps-domain fold (``mean * e * 2/3 >> 16``)
    is 64-bit host-side arithmetic in the Rust coordinator and is not
    mirrored here.
    """
    r = mix32(
        seed ^ _U(0xA511E9B3) ^ (g * _U(0x9E3779B1) + thread * _U(0x85EBCA6B))
    ) | _U(1)
    clz = lax.clz(r)  # 0..=31: r | 1 is never zero
    norm = r << clz  # normalized mantissa in [2^31, 2^32)
    frac_q16 = (norm & _U(0x7FFFFFFF)) >> _U(15)
    return ((clz + _U(1)) << _U(16)) - frac_q16


def arrival_phase_u16(g, seed, thread):
    """Uniform u16 phase-selection draw for op ``g`` (burst arrivals pick
    the short or long hyperexponential phase with it).  Mirrors
    ``arrival_phase_u16`` in rust/src/workloads/tracegen.rs."""
    return mix32(
        seed ^ _U(0x94D049BB) ^ (g * _U(0xC2B2AE35) + thread * _U(0x27D4EB2F))
    ) >> _U(16)


def _kernel(seed_ref, base_ref, params_ref, op_ref, addr_ref, extra_ref):
    j = pl.program_id(0)
    seed = seed_ref[0].astype(jnp.uint32)
    base = base_ref[0].astype(jnp.uint32)
    params = params_ref[...]
    g = base + j.astype(jnp.uint32) * _U(BLOCK) + lax.iota(jnp.uint32, BLOCK)
    op, addr, extra = gen_fields(g, seed, params)
    op_ref[...] = lax.bitcast_convert_type(op, jnp.int32)
    addr_ref[...] = lax.bitcast_convert_type(addr, jnp.int32)
    extra_ref[...] = lax.bitcast_convert_type(extra, jnp.int32)


def trace_block(seed, base, params):
    """Generate ``N_OPS`` trace ops for one thread.

    seed: int32[1]; base: int32[1] (global op index of the block's first
    op); params: int32[16].  Returns (op, addr, extra): int32[N_OPS] each
    (addr/extra carry uint32 bit patterns).
    """
    out = jax.ShapeDtypeStruct((N_OPS,), jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid=(N_OPS // BLOCK,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((1,), lambda j: (0,)),
            pl.BlockSpec((NUM_PARAMS,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda j: (j,)),
            pl.BlockSpec((BLOCK,), lambda j: (j,)),
            pl.BlockSpec((BLOCK,), lambda j: (j,)),
        ],
        out_shape=[out, out, out],
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls
    )(seed, base, params)
