"""AOT bridge: lower the Layer-2 entry points to HLO *text* artifacts.

HLO text — not ``lowered.compile()`` / ``.serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which
the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The HLO text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (run by
``make artifacts``; a no-op when artifacts are newer than their inputs,
courtesy of the Makefile).  Also writes ``manifest.txt`` with the shape
contract the Rust runtime asserts at load time.
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "trace_gen": model.lower_trace_block,
    "latest_version": model.lower_latest_versions,
}

MANIFEST = """\
# recxl artifact manifest (asserted by rust/src/runtime/mod.rs)
n_ops={n_ops}
num_params={num_params}
n_log={n_log}
q={q}
trace_gen=trace_gen.hlo.txt
latest_version=latest_version.hlo.txt
"""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    mpath = os.path.join(args.out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write(
            MANIFEST.format(
                n_ops=model.N_OPS,
                num_params=model.NUM_PARAMS,
                n_log=model.N_LOG,
                q=model.Q,
            )
        )
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
