"""Kernel-vs-reference tests for the latest_version Pallas kernel."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import latest_version as lv
from compile.kernels import ref


def pad(xs, n, fill=0):
    out = np.full(n, fill, dtype=np.int32)
    out[: len(xs)] = xs
    return jnp.asarray(out)


def run_both(q, la, ts, valid, val):
    args = (
        pad(q, lv.Q, fill=-1),
        pad(la, lv.N_LOG, fill=-1),
        pad(ts, lv.N_LOG),
        pad(valid, lv.N_LOG),
        pad(val, lv.N_LOG),
    )
    got = lv.latest_versions(*args)
    want = ref.latest_versions_ref(*args)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


def test_simple_latest_wins():
    # two updates to addr 100: ts 1 then ts 5 -> value 222
    got, want = run_both([100], [100, 100], [1, 5], [1, 1], [111, 222])
    assert got[0][0] == 5 * lv.N_LOG + 1
    assert got[1][0] == 222
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


def test_no_match_returns_minus_one():
    got, _ = run_both([77], [100], [1], [1], [9])
    assert got[0][0] == -1


def test_invalid_entries_ignored():
    got, _ = run_both([100], [100, 100], [1, 5], [1, 0], [111, 222])
    assert got[0][0] == 1 * lv.N_LOG + 0
    assert got[1][0] == 111


def test_tie_broken_toward_later_log_index():
    # same ts logged twice (two replicas' copies): later index wins
    got, _ = run_both([100], [100, 100], [3, 3], [1, 1], [5, 6])
    assert got[1][0] == 6


def test_matches_across_tile_boundary():
    # place the winning entry in the last grid tile
    n = lv.N_LOG
    la = np.full(n, -1, dtype=np.int32)
    ts = np.zeros(n, dtype=np.int32)
    valid = np.ones(n, dtype=np.int32)
    val = np.zeros(n, dtype=np.int32)
    la[10] = 42
    ts[10] = 7
    val[10] = 1000
    la[n - 1] = 42
    ts[n - 1] = 9
    val[n - 1] = 2000
    got = lv.latest_versions(
        pad([42], lv.Q, fill=-1), jnp.asarray(la), jnp.asarray(ts),
        jnp.asarray(valid), jnp.asarray(val),
    )
    assert np.asarray(got[0])[0] == 9 * lv.N_LOG + (n - 1)
    assert np.asarray(got[1])[0] == 2000


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_kernel_matches_ref_hypothesis(data):
    n_entries = data.draw(st.integers(min_value=0, max_value=lv.N_LOG))
    n_q = data.draw(st.integers(min_value=1, max_value=lv.Q))
    addr_space = data.draw(st.integers(min_value=1, max_value=50))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    la = rng.integers(0, addr_space, n_entries).astype(np.int32)
    ts = rng.integers(0, 1 << 15, n_entries).astype(np.int32)
    valid = rng.integers(0, 2, n_entries).astype(np.int32)
    val = rng.integers(-(2**31), 2**31 - 1, n_entries, dtype=np.int64).astype(np.int32)
    q = rng.integers(0, addr_space + 5, n_q).astype(np.int32)
    got, want = run_both(list(q), list(la), list(ts), list(valid), list(val))
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
