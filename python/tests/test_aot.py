"""Lowering/AOT tests: both entry points lower to parseable HLO text with
the shapes the Rust runtime expects."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_trace_block_lowers_to_hlo_text():
    text = aot.to_hlo_text(model.lower_trace_block())
    assert "ENTRY" in text
    assert f"s32[{model.N_OPS}]" in text


def test_latest_versions_lowers_to_hlo_text():
    text = aot.to_hlo_text(model.lower_latest_versions())
    assert "ENTRY" in text
    assert f"s32[{model.Q}]" in text


def test_model_entry_points_execute():
    s = jnp.array([1], dtype=jnp.int32)
    p = jnp.zeros(model.NUM_PARAMS, dtype=jnp.int32)
    ops, addrs, extras = model.trace_block(s, s, p)
    assert ops.shape == (model.N_OPS,)
    q = jnp.zeros(model.Q, dtype=jnp.int32)
    n = jnp.zeros(model.N_LOG, dtype=jnp.int32)
    key, val = model.latest_versions(q, n, n, n, n)
    assert key.shape == (model.Q,)
    assert np.asarray(key)[0] >= -1
