"""Kernel-vs-reference tests for the trace_gen Pallas kernel.

The kernel is integer-exact: every assertion is bitwise equality against the
pure-jnp oracle, plus structural invariants on the generated stream.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels import trace_gen as tg


def make_params(
    thread_id=0,
    p_load=0.30,
    p_store=0.20,
    p_lock=0.001,
    p_remote=0.5,
    shared_log2=16,
    priv_log2=12,
    p_seq=0.6,
    run_log2=3,
    p_hot=0.2,
    hot_log2=8,
    cs_len=8,
):
    f16 = lambda p: int(round(p * 65536))
    v = [0] * tg.NUM_PARAMS
    v[0] = thread_id
    v[1] = f16(p_load)
    v[2] = f16(p_load + p_store)
    v[3] = f16(p_load + p_store + p_lock)
    v[5] = f16(p_remote)
    v[6] = shared_log2
    v[7] = priv_log2
    v[8] = f16(p_seq)
    v[9] = run_log2
    v[10] = f16(p_hot)
    v[11] = hot_log2
    v[12] = cs_len
    return jnp.array(v, dtype=jnp.int32)


def run_both(seed, base, params):
    s = jnp.array([seed], dtype=jnp.int32)
    b = jnp.array([base], dtype=jnp.int32)
    got = tg.trace_block(s, b, params)
    want = ref.trace_block_ref(s, b, params)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


def test_kernel_matches_ref_exactly():
    got, want = run_both(42, 0, make_params())
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_kernel_matches_ref_nonzero_base():
    got, want = run_both(7, 3 * tg.N_OPS, make_params(thread_id=17))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_deterministic():
    a, _ = run_both(123, 0, make_params())
    b, _ = run_both(123, 0, make_params())
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_blocks_are_counter_based():
    """Block [base, base+N) must equal the matching slice of a wider stream:
    ops are pure functions of the global index (random access, no state)."""
    p = make_params(thread_id=3)
    a, _ = run_both(9, 0, p)
    b, _ = run_both(9, tg.BLOCK, p)  # overlaps a by N_OPS - BLOCK
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x[tg.BLOCK :], y[: tg.N_OPS - tg.BLOCK])


def test_op_distribution_tracks_thresholds():
    got, _ = run_both(1, 0, make_params(p_load=0.4, p_store=0.3))
    op = np.asarray(got[0])
    n = op.size
    assert abs((op == 1).mean() - 0.4) < 0.03
    assert abs((op == 2).mean() - 0.3) < 0.03
    assert (op == 3).mean() < 0.01


def test_address_structure():
    got, _ = run_both(5, 0, make_params(shared_log2=10, priv_log2=8, thread_id=21))
    op, addr = got[0], got[1].astype(np.uint32)
    mem = (op == 1) | (op == 2)
    a = addr[mem]
    assert np.all(addr[~mem] == 0)
    assert np.all(a % 4 == 0), "word aligned"
    remote = (a >> 31) == 1
    # remote lines within the 2^10-line shared footprint
    rl = (a[remote] >> 6) & ((1 << 25) - 1)
    assert np.all(rl < (1 << 10))
    # local addresses carry the thread id and stay within 2^8 lines
    la = a[~remote]
    assert np.all((la >> 24) == 21)
    assert np.all(((la >> 6) & ((1 << 18) - 1)) < (1 << 8))


def test_seq_runs_share_lines():
    """With p_seq=1 and run_len 2^3, store addresses inside an aligned run of
    8 global indices target a single line (the SB-coalescing structure)."""
    p = make_params(
        p_load=0.0, p_store=1.0, p_lock=0.0, p_remote=1.0, p_seq=1.0, run_log2=3
    )
    got, _ = run_both(11, 0, p)
    addr = got[1].astype(np.uint32)
    lines = addr >> 6
    runs = lines.reshape(-1, 8)
    assert np.all(runs == runs[:, :1])


def test_lock_extra_encoding():
    p = make_params(p_load=0.0, p_store=0.0, p_lock=1.0, cs_len=13)
    got, _ = run_both(2, 0, p)
    op, extra = got[0], got[2].astype(np.uint32)
    assert np.all(op == 3)
    assert np.all((extra & 0xFF) == 13)
    assert np.all((extra >> 8) < 64)


def test_zipf_gate_changes_stream_and_matches_kernel():
    """p[15] != 0 switches random accesses to the dyadic zipf draw; the
    Pallas kernel and the jnp oracle must still agree bit-for-bit, and the
    gated stream must differ from the historical one."""
    v = np.asarray(make_params()).tolist()
    v[15] = 1
    p_zipf = jnp.array(v, dtype=jnp.int32)
    got, want = run_both(42, 0, p_zipf)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    base, _ = run_both(42, 0, make_params())
    assert any(
        not np.array_equal(x, y) for x, y in zip(got, base)
    ), "the zipf gate must actually change the stream"


def test_zipf_concentrates_mass_on_low_ranks():
    """Dyadic zipf(1): each rank octave carries equal mass, so the lowest
    2^4 lines of a 2^16-line footprint draw ~4/16 of random accesses."""
    p = make_params(p_load=0.5, p_store=0.5, p_lock=0.0, p_remote=1.0, p_seq=0.0, p_hot=0.0)
    v = np.asarray(p).tolist()
    v[15] = 1
    got, _ = run_both(7, 0, jnp.array(v, dtype=jnp.int32))
    op, addr = got[0], got[1].astype(np.uint32)
    mem = (op == 1) | (op == 2)
    lines = (addr[mem] >> 6) & ((1 << 16) - 1)
    frac = (lines < 16).mean()
    assert 0.15 < frac < 0.40, f"low-rank fraction {frac} should be near 4/16"


def test_arrival_draws_match_rust_contract():
    """The open-loop arrival primitives: counter-based, strictly positive,
    mean exactly 1.5 * 2^16 (clz contributes 1 octave, frac half of one).
    Pinned values lock the mix constants against drift from the Rust side."""
    g = jnp.arange(65536, dtype=jnp.uint32)
    e = np.asarray(tg.arrival_e_q16(g, tg._U(1), tg._U(0)), dtype=np.uint64)
    assert np.all(e > 0), "a zero draw would glue two arrivals"
    mean = e.mean() / 65536.0
    assert abs(mean - 1.5) < 0.03, f"mean e = {mean}"
    # pure function of (seed, thread, index): same in, same out; any
    # coordinate changed, different stream
    one = lambda gg, s, t: int(
        np.asarray(tg.arrival_e_q16(tg._U(gg), tg._U(s), tg._U(t)))
    )
    assert one(9, 42, 3) == one(9, 42, 3)
    assert one(9, 42, 3) != one(10, 42, 3)
    assert one(9, 42, 3) != one(9, 42, 4)
    assert one(9, 42, 3) != one(9, 43, 3)
    # phase draws are uniform u16
    ph = np.asarray(tg.arrival_phase_u16(g, tg._U(1), tg._U(0)))
    assert np.all(ph < 65536)
    assert abs(ph.mean() - 32767.5) < 500


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    base=st.integers(min_value=0, max_value=2**18).map(lambda x: x * tg.N_OPS),
    thread=st.integers(min_value=0, max_value=63),
    p_load=st.integers(min_value=0, max_value=60000),
    p_store_inc=st.integers(min_value=0, max_value=5000),
    shared_log2=st.integers(min_value=4, max_value=24),
    priv_log2=st.integers(min_value=4, max_value=18),
    run_log2=st.integers(min_value=0, max_value=6),
    p_seq=st.integers(min_value=0, max_value=65535),
    p_hot=st.integers(min_value=0, max_value=65535),
    hot_log2=st.integers(min_value=2, max_value=12),
)
def test_kernel_matches_ref_hypothesis(
    seed, base, thread, p_load, p_store_inc, shared_log2, priv_log2,
    run_log2, p_seq, p_hot, hot_log2,
):
    v = [0] * tg.NUM_PARAMS
    v[0] = thread
    v[1] = p_load
    v[2] = min(65535, p_load + p_store_inc)
    v[3] = min(65535, v[2] + 50)
    v[5] = 30000
    v[6] = shared_log2
    v[7] = priv_log2
    v[8] = p_seq
    v[9] = run_log2
    v[10] = p_hot
    v[11] = hot_log2
    v[12] = 5
    params = jnp.array(v, dtype=jnp.int32)
    got, want = run_both(seed, base, params)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
